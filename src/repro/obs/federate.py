"""Metrics federation: every process's registry merged over the KV fabric.

PR 9's ``MetricsRegistry`` stops at the process boundary. This module ships
each process's ``collect()`` snapshot through the same KV plane the fleet
control loop already uses — versioned, heartbeat-stamped records with
optimistic-transaction publishing (``FleetPublisher``) and heartbeat-age
staleness (``FleetAggregator``) — under a separate ``obs/`` key prefix so
metrics traffic never collides with rendezvous coordination state.

* :class:`MetricsPublisher` — a ``FleetPublisher`` whose record payload is
  ``{"region", "metrics": registry.collect()}`` instead of a flat telemetry
  snapshot. Call ``maybe_publish()`` from any convenient loop; it is the
  heartbeat.
* :class:`MetricsFederator` — reads all fresh member records and folds them
  into ONE fleet-wide view. Merge rules are name-driven, mirroring the
  fleet aggregator's quantile hygiene: upper quantiles (p95/p99/max/age)
  take the max across members — the conservative combine, a fleet is as
  slow as its slowest member; central/ratio statistics (p50/mean/ratio)
  take the load-weighted mean; everything else (counters, rates) sums.

The federated view is exposed three ways: ``view()`` (flat ``obs.*`` keys
for SLO engines and policy predicates, including per-region breakdowns),
``federated_registry()`` (a point-in-time ``MetricsRegistry`` whose
instances are ``member/instance``-labeled plus a ``_fleet`` merged row —
reusing the stock JSON/Prometheus exporters verbatim), and the
``SignalSource`` protocol (``read()``), so a ``FleetAggregator`` or
controller can merge ``obs.*`` keys like any other signal feed.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

from repro.core.rendezvous import KVStore
from repro.fleet.aggregate import FleetAggregator
from repro.fleet.publish import FleetPublisher
from repro.obs.metrics import MetricsRegistry

__all__ = ["MetricsPublisher", "MetricsFederator", "OBS_PLANE"]

#: KV key prefix for the observability plane (vs ``"fleet"`` coordination).
OBS_PLANE = "obs"

# merge-mode vocabularies: substring match on the flattened metric key
_MAX_TOKENS = ("p95", "p99", "p999", "max", "age", "imbalance", "uptime")
_MEAN_TOKENS = ("p50", "p10", "mean", "avg", "ratio", "frac", "per_op",
                "utilization")


class MetricsPublisher(FleetPublisher):
    """Publish one process's ``MetricsRegistry`` snapshot to the obs plane.

    Args:
        store, fleet_id, member: where and as whom to publish.
        registry: the process-local ``MetricsRegistry``.
        region: breakdown label for ``MetricsFederator.per_region`` /
            ``obs.region.<region>.*`` keys.
        period_s / max_retries / now: as ``FleetPublisher``.

    Registered sources must sample non-destructively — wrap a
    ``ConnTelemetry`` as ``lambda: t.snapshot(reset_window=False)`` rather
    than ``registry.watch``-ing it directly, or publishing would steal the
    local controller's rate window.
    """

    def __init__(self, store: KVStore, fleet_id: str, member: str,
                 registry: MetricsRegistry, *, region: str = "default",
                 period_s: float = 0.05, max_retries: int = 32,
                 now: Callable[[], float] = time.monotonic):
        super().__init__(store, fleet_id, member, telemetry=registry,
                         period_s=period_s, reset_window=False,
                         max_retries=max_retries, plane=OBS_PLANE, now=now)
        self.registry = registry
        self.region = region

    def _snapshot(self) -> Dict[str, Any]:
        return {"region": self.region, "metrics": self.registry.collect()}


# one flattened sample: (member, region, family, key, value, weight)
_Row = Tuple[str, str, str, str, float, float]


def _as_float(val: Any) -> Optional[float]:
    if isinstance(val, bool):
        return float(val)
    if isinstance(val, (int, float)):
        return float(val)
    return None


def _merge_mode(key: str) -> str:
    k = key.lower()
    if any(t in k for t in _MAX_TOKENS):
        return "max"
    if any(t in k for t in _MEAN_TOKENS):
        return "mean"
    return "sum"


def _flatten(metrics: Mapping[str, Mapping[str, Any]]
             ) -> List[Tuple[str, str, float]]:
    """``registry.collect()``'s ``{family: {instance: {key: val}}}`` down to
    ``[(family, key, value)]``; one-level nested dicts become dotted keys,
    non-numerics (incl. ``_error`` markers) are dropped from the merge —
    they stay visible in the per-member JSON."""
    rows: List[Tuple[str, str, float]] = []
    for family, insts in metrics.items():
        for metrics_d in insts.values():
            if not isinstance(metrics_d, Mapping):
                continue
            for key, val in metrics_d.items():
                if key.startswith("_"):
                    continue
                if isinstance(val, Mapping):
                    for sub, sv in val.items():
                        num = _as_float(sv)
                        if num is not None:
                            rows.append((family, f"{key}.{sub}", num))
                    continue
                num = _as_float(val)
                if num is not None:
                    rows.append((family, key, num))
    return rows


def _fold(rows: List[_Row]) -> Dict[str, Dict[str, float]]:
    """Merge flattened rows into ``{family: {key: value}}`` by mode."""
    acc: Dict[Tuple[str, str], List[Tuple[float, float]]] = {}
    for _m, _r, family, key, val, weight in rows:
        acc.setdefault((family, key), []).append((val, weight))
    out: Dict[str, Dict[str, float]] = {}
    for (family, key), pairs in acc.items():
        mode = _merge_mode(key)
        if mode == "max":
            v = max(p[0] for p in pairs)
        elif mode == "mean":
            wsum = sum(w for _, w in pairs)
            v = (sum(x * w for x, w in pairs) / wsum if wsum > 0
                 else sum(x for x, _ in pairs) / len(pairs))
        else:
            v = sum(x for x, _ in pairs)
        out.setdefault(family, {})[key] = v
    return out


class MetricsFederator:
    """Fold obs-plane member records into one fleet-wide metrics view.

    Args:
        store, fleet_id: where the ``MetricsPublisher``s write.
        ttl_s: heartbeat age beyond which a member is stale (and, with
            ``expire=True``, physically removed — obs-plane expiry never
            touches rendezvous membership).
        now: clock override for deterministic tests.
    """

    name = "obs"  # SignalSource protocol

    def __init__(self, store: KVStore, fleet_id: str, *, ttl_s: float = 1.0,
                 expire: bool = True,
                 now: Callable[[], float] = time.monotonic):
        self.fleet_id = fleet_id
        self._now = now
        self._agg = FleetAggregator(store, fleet_id, ttl_s=ttl_s,
                                    expire=expire, plane=OBS_PLANE, now=now)

    # -- raw member view -------------------------------------------------------
    def members(self, now: Optional[float] = None
                ) -> Tuple[Dict[str, dict], List[str]]:
        """(fresh records by member, stale member names)."""
        return self._agg.member_records(now)

    @property
    def expired_total(self) -> int:
        return self._agg.expired_total

    def _rows(self, fresh: Dict[str, dict]) -> List[_Row]:
        rows: List[_Row] = []
        for member, rec in fresh.items():
            snap = rec.get("snapshot") or {}
            region = snap.get("region") or "default"
            flat = _flatten(snap.get("metrics") or {})
            # load weight for mean merges: the member's op rate if its
            # metrics carry one, else uniform
            weight = sum(v for _f, k, v in flat if k.endswith("ops_per_s"))
            weight = weight if weight > 0 else 1.0
            rows.extend((member, region, f, k, v, weight)
                        for f, k, v in flat)
        return rows

    # -- merged views ----------------------------------------------------------
    def merged(self, now: Optional[float] = None
               ) -> Dict[str, Dict[str, float]]:
        """Fleet-wide ``{family: {key: value}}`` across all fresh members."""
        fresh, _stale = self.members(now)
        return _fold(self._rows(fresh))

    def per_region(self, now: Optional[float] = None
                   ) -> Dict[str, Dict[str, Dict[str, float]]]:
        """``{region: {family: {key: value}}}`` breakdown."""
        fresh, _stale = self.members(now)
        by_region: Dict[str, List[_Row]] = {}
        for row in self._rows(fresh):
            by_region.setdefault(row[1], []).append(row)
        return {region: _fold(rows) for region, rows in by_region.items()}

    def view(self, now: Optional[float] = None) -> Dict[str, Any]:
        """One flat ``obs.*`` dict — the SLO engine's and policy layer's
        input. Keys: ``obs.members``/``obs.stale_members``/
        ``obs.availability``/``obs.heartbeat_age_s``, fleet-merged
        ``obs.<family>.<key>``, per-region
        ``obs.region.<region>.<family>.<key>``, and the
        ``obs.member_ops_per_s`` load-weight detail."""
        now = self._now() if now is None else now
        fresh, stale = self.members(now)
        rows = self._rows(fresh)
        total = len(fresh) + len(stale)
        out: Dict[str, Any] = {
            "obs.members": len(fresh),
            "obs.stale_members": len(stale),
            "obs.availability": (len(fresh) / total) if total else 1.0,
            "obs.heartbeat_age_s": (max(now - rec.get("at", now)
                                        for rec in fresh.values())
                                    if fresh else None),
        }
        for family, keys in _fold(rows).items():
            for key, val in keys.items():
                out[f"obs.{family}.{key}"] = val
        by_region: Dict[str, List[_Row]] = {}
        for row in rows:
            by_region.setdefault(row[1], []).append(row)
        for region, rrows in by_region.items():
            for family, keys in _fold(rrows).items():
                for key, val in keys.items():
                    out[f"obs.region.{region}.{family}.{key}"] = val
        weights: Dict[str, float] = {}
        for member, _r, _f, key, val, _w in rows:
            if key.endswith("ops_per_s"):
                weights[member] = weights.get(member, 0.0) + val
        out["obs.member_ops_per_s"] = weights
        return out

    # -- SignalSource protocol -------------------------------------------------
    def read(self, now: Optional[float] = None) -> Dict[str, Any]:
        return self.view(now)

    # -- exporter bridge -------------------------------------------------------
    def federated_registry(self, now: Optional[float] = None
                           ) -> MetricsRegistry:
        """A point-in-time ``MetricsRegistry`` over the federated snapshot.

        Per-member sources keep their family but are re-instanced as
        ``<member>/<original instance>``; the fleet-merged fold is added
        under instance ``_fleet``. The stock ``to_prometheus`` then emits
        multi-member-labeled samples with no new exporter code.
        """
        reg = MetricsRegistry()
        fresh, _stale = self.members(now)
        for member, rec in sorted(fresh.items()):
            snap = rec.get("snapshot") or {}
            for family, insts in (snap.get("metrics") or {}).items():
                for inst, metrics in insts.items():
                    reg.register(family, lambda m=metrics: m,
                                 instance=f"{member}/{inst}")
        for family, keys in _fold(self._rows(fresh)).items():
            reg.register(family, lambda m=keys: m, instance="_fleet")
        return reg
