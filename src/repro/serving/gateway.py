"""Region gateway: the hub-side peer of the per-region stack Select.

A ``WanGateway`` terminates both options a region client can pick
(docs/architecture.md §9):

  * ``<addr>/fast`` — the clean-DCN fast path: plain ``FabricTransport``
    frames, echoed straight back to the sender (request/reply RTT probe).
  * ``<addr>/wan``  — the hostile-link path: ``WanLinkChunnel`` frames
    (go-back-N windows of MTU-sized chunks) served through a
    ``ReliableChannel`` with bounded reassembly; delivery is confirmed by
    the window acks themselves, keepalive probes are answered from the
    same handler.

One gateway serves many regions; reassembly state is bounded by
``max_partial`` so a client partitioned away mid-blob cannot pin memory.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List

from repro.comm.wire import Reassembler, decode_blob
from repro.core.fabric import Fabric, ReliableChannel


class WanGateway:
    """Serves the fast path and the WAN link for one hub address."""

    def __init__(self, fabric: Fabric, addr: str, *, use_kernel: bool = False,
                 max_partial: int = 64, poll_s: float = 0.005):
        self.addr = addr
        self.use_kernel = use_kernel
        self.poll_s = poll_s
        self.fast_ep = fabric.register(addr + "/fast")
        self.wan_ep = fabric.register(addr + "/wan")
        self._chan = ReliableChannel(self.wan_ep, peer=addr + "/wan")
        self._reasm = Reassembler(max_partial=max_partial)
        # advisory counters (GIL-ridden ints, like FabricCounters)
        self.fast_msgs = 0
        self.wan_frames = 0
        self.wan_blobs = 0
        self.wan_msgs = 0
        self.wan_pings = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _loop(self):
        bufs: List[Any] = [None] * 256
        while not self._stop.is_set():
            served = self._chan.serve_one(self._on_wan_frame,
                                          timeout=self.poll_s)
            self._pump_fast(bufs, block=not served)

    def _pump_fast(self, bufs: List[Any], *, block: bool) -> None:
        """Echo fast-path frames back to their senders, batched per source."""
        n = self.fast_ep.recv_many(bufs, timeout=self.poll_s if block else 0.0)
        if not n:
            return
        by_src: Dict[str, List[Any]] = {}
        for k in range(n):
            src, m = bufs[k]
            by_src.setdefault(src, []).append(m)
        for src, ms in by_src.items():
            self.fast_ep.send_batch(src, ms)
        self.fast_msgs += n  # lint: allow[unguarded-attr] advisory counter riding the GIL (FabricCounters convention); stats() only reads

    def _on_wan_frame(self, src: str, body: Any) -> Any:
        """ReliableChannel handler: the returned dict rides back as the ack
        body, so window acks double as delivery confirmation."""
        self.wan_frames += 1
        if isinstance(body, dict):
            if "_wire" in body:
                done = self._reasm.ingest(body)
                if done is not None:
                    payload, hdr = done
                    self.wan_blobs += 1
                    if hdr.get("kind") == "raw":
                        self.wan_msgs += 1
                    else:
                        self.wan_msgs += len(decode_blob(
                            payload, hdr, use_kernel=self.use_kernel))
                return {"ok": True}
            if "_ka" in body:
                self.wan_pings += 1
                return {"pong": True}
            if "_obj" in body:
                self.wan_msgs += 1
                return {"ok": True, "rid": body["_obj"].get("rid")
                        if isinstance(body["_obj"], dict) else None}
        self.wan_msgs += 1
        return {"ok": True}

    def stats(self) -> dict:
        return {
            "fast_msgs": self.fast_msgs,
            "wan_frames": self.wan_frames,
            "wan_blobs": self.wan_blobs,
            "wan_msgs": self.wan_msgs,
            "wan_pings": self.wan_pings,
            "partial_blobs": self._reasm.partial_count(),
            "evicted_partials": self._reasm.evicted,
            "dup_replies": self._chan.dup_replies,
        }

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1.0)
        self.fast_ep.close()
        self.wan_ep.close()
