"""Sharded key-value serving with reconfigurable load balancing (paper §7.3).

Two routing chunnels over the host fabric:

  ClientShardChunnel  the client evaluates hash(key) % n_shards and sends
                      DIRECTLY to the owning backend (no extra hop). Negotiation
                      hands the client a nonce so backends accept its requests.
  ServerRouterChunnel requests go to a router process which forwards to the
                      right backend (extra hop + router queueing, but backends
                      can be re-provisioned without touching clients).

The benchmark (benchmarks/bench_sharding.py ~ Fig. 6) measures p50/p95 latency
vs offered load for both, and the reconfiguration between them mid-run;
``routing_stack()`` packages the two as a Select so a ReconfigController can
switch them from live telemetry (benchmarks/bench_reconfigure.py closes that
loop end-to-end).
"""
from __future__ import annotations

import hashlib
import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.core import Fabric, FabricTransport, LinkModel, Select, Stack, make_stack
from repro.core.capability import CapabilitySet
from repro.core.chunnel import Chunnel, Datapath, WireType
from repro.core.controller import (
    PolicyContext,
    Rule,
    above,
    all_of,
    below,
    register_policy,
)
from repro.core.cost import CostModel

KV_REQ = WireType.of("kvreq")


def shard_of(key: str, n: int) -> int:
    return int(hashlib.md5(key.encode()).hexdigest(), 16) % n


class KVBackend:
    """One shard server: applies PUT/GET against a local dict."""

    def __init__(self, fabric: Fabric, addr: str, *, service_time_s: float = 0.0):
        self.addr = addr
        self.ep = fabric.register(addr)
        self.data: Dict[str, Any] = {}
        self.service_time_s = service_time_s
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _loop(self):
        while not self._stop.is_set():
            got = self.ep.recv(timeout=0.05)
            if got is None:
                continue
            src, msg = got
            if not isinstance(msg, dict) or "op" not in msg:
                continue
            if self.service_time_s:
                time.sleep(self.service_time_s)
            if msg["op"] == "put":
                self.data[msg["key"]] = msg["val"]
                out = {"ok": True, "rid": msg["rid"]}
            else:
                out = {"ok": True, "val": self.data.get(msg["key"]), "rid": msg["rid"]}
            self.ep.send(msg["reply_to"], out)

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1.0)
        self.ep.close()


class Router:
    """Extra-hop router used by the server-side chunnel."""

    def __init__(self, fabric: Fabric, addr: str, backends: List[str]):
        self.addr = addr
        self.ep = fabric.register(addr)
        self.backends = backends
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _loop(self):
        while not self._stop.is_set():
            got = self.ep.recv(timeout=0.05)
            if got is None:
                continue
            src, msg = got
            if isinstance(msg, dict) and "key" in msg:
                self.ep.send(self.backends[shard_of(msg["key"], len(self.backends))], msg)

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1.0)
        self.ep.close()


@dataclass
class ClientShardChunnel(Chunnel):
    """Client-side sharding: compositional capability (one side suffices)."""

    backends: tuple = ()
    upper_type = KV_REQ
    lower_type = KV_REQ

    @property
    def name(self):
        return "ClientShard"

    def capabilities(self):
        return CapabilitySet.compose("route:client-shard")

    def cost_model(self):
        # direct to the owning backend: no extra hop, no router queueing
        return CostModel(op_latency_s=1.6e-3, switch_blip_s=1e-4)

    def connect_wrap(self, inner):
        return _RoutedDP(self, inner, lambda m: self.backends[
            shard_of(m["key"], len(self.backends))])


@dataclass
class ServerRouterChunnel(Chunnel):
    router_addr: str = "router"
    upper_type = KV_REQ
    lower_type = KV_REQ

    @property
    def name(self):
        return "ServerRouter"

    def capabilities(self):
        return CapabilitySet.compose("route:server")

    def cost_model(self):
        # one extra hop + router queueing, but backends re-provision freely
        return CostModel(op_latency_s=2.4e-3, switch_blip_s=1e-4)

    def connect_wrap(self, inner):
        return _RoutedDP(self, inner, lambda m: self.router_addr)


class _RoutedDP(Datapath):
    def __init__(self, ch, inner, pick):
        self.ch = ch
        self.inner = inner
        self.pick = pick

    def send(self, msgs):
        out = []
        for m in msgs:  # annotate routing decisions; forwarded as ONE batch
            m = dict(m)
            m["_route_to"] = self.pick(m)
            out.append(m)
        if self.inner is not None and out:
            self.inner.send(out)

    def recv(self, buf, timeout=None):
        return self.inner.recv(buf, timeout) if self.inner else 0


class AddressedTransport(Chunnel):
    """Transport that honours the routing decision in ``_route_to``."""

    upper_type = KV_REQ
    lower_type = WireType.of("unit")

    def __init__(self, ep):
        self.ep = ep

    @property
    def name(self):
        return "AddressedTransport"

    def connect_wrap(self, inner):
        ep = self.ep

        class DP(Datapath):
            def send(self, msgs):
                by_dst: Dict[str, list] = {}
                for m in msgs:  # group by destination; one send_batch per peer
                    by_dst.setdefault(m.pop("_route_to"), []).append(m)
                for dst, batch in by_dst.items():
                    ep.send_batch(dst, batch)

            def recv(self, buf, timeout=None):
                tmp: List[Any] = [None] * len(buf)
                got = ep.recv_many(tmp, timeout=timeout)
                for k in range(got):
                    buf[k] = tmp[k][1]
                return got

        return DP()


@register_policy("kv_load_adaptive")
def kv_load_adaptive_policy(ctx: PolicyContext) -> List[Rule]:
    """The §7.3 load-balancing policy, shipped through the plugin registry:
    offered load above ``high_ops_per_s`` moves the routing Select to the
    direct ClientShard option (no router hop/queueing under load); load
    draining below ``low_ops_per_s`` moves back to ServerRouter (backends
    re-provisionable behind the router). Keep the two thresholds apart — the
    gap is the hysteresis band."""
    p = ctx.params
    high = p.get("high_ops_per_s", 150.0)
    low = p.get("low_ops_per_s", 120.0)
    hold = p.get("hold", 2)
    return [
        Rule("high-load->client-shard", above("ops_per_s", high),
             ctx.candidate_named("ClientShard").target, hold=hold, priority=1),
        Rule("low-load->server-router", below("ops_per_s", low),
             ctx.candidate_named("ServerRouter").target, hold=hold, priority=0),
    ]


@register_policy("kv_fleet_adaptive")
def kv_fleet_adaptive_policy(ctx: PolicyContext) -> List[Rule]:
    """The §7.3 load-balancing policy at FLEET scope: predicates read the
    ``FleetAggregator`` snapshot (``fleet.*``/``ext.*`` keys), and the rules
    run in a ``repro.fleet.fleet_controller`` so the switch commits once,
    fleet-wide, in a single rendezvous epoch — instead of N per-client
    controllers crossing their own thresholds at their own times.

      fleet.offered_qps > fleet_high_qps  ⇒ ClientShard (direct; no router
                                            hop/queueing under aggregate load)
      fleet.offered_qps < fleet_low_qps   ⇒ ServerRouter (backends
                                            re-provisionable behind the router)

    With ``spot_cap_usd_per_h`` set, a MULTI-SOURCE clause combines the fleet
    aggregate with an external ``SignalSource`` value: a spot-price spike
    while aggregate load is below the high-water mark consolidates traffic
    behind the router (priority between the two load rules), so operators can
    shrink the backend fleet while the market is expensive.

    With ``slo`` set (an SLO name whose ``slo.*`` signals reach the fleet
    snapshot — ``aggregator.add_source(engine)``), a burn-rate clause
    OUTRANKS both load rules: an alarmed latency budget moves traffic to the
    direct ClientShard path (drops the router hop) regardless of where
    offered load sits — intent-level arming, not raw thresholds."""
    p = ctx.params
    high = p.get("fleet_high_qps", 200.0)
    low = p.get("fleet_low_qps", 120.0)
    hold = p.get("hold", 2)
    rules = [
        Rule("fleet-high-load->client-shard", above("fleet.offered_qps", high),
             ctx.candidate_named("ClientShard").target, hold=hold, priority=2),
        Rule("fleet-low-load->server-router", below("fleet.offered_qps", low),
             ctx.candidate_named("ServerRouter").target, hold=hold, priority=0),
    ]
    spot_cap = p.get("spot_cap_usd_per_h")
    if spot_cap is not None:
        rules.insert(1, Rule(
            "fleet-spot-spike->server-router",
            all_of(above("ext.spot_usd_per_h", spot_cap),
                   below("fleet.offered_qps", high)),
            ctx.candidate_named("ServerRouter").target,
            hold=hold, priority=1))
    slo = p.get("slo")
    if slo is not None:
        rules.insert(0, Rule(
            "fleet-slo-burn->client-shard",
            above(f"slo.{slo}.alarm", 0.5),
            ctx.candidate_named("ClientShard").target,
            hold=p.get("slo_hold", 1), priority=3))
    return rules


def routing_stack(ep, backends, router_addr: str = "router", *,
                  prefer: str = "server") -> Stack:
    """The §7.3 routing Select over the addressed transport: ServerRouter
    (backends re-provisionable behind the router) vs ClientShard (direct to
    the owning backend — no hop, no router queueing). ``prefer`` sets the
    operator's default; the reconfiguration controller switches between the
    two options at runtime from offered-load/latency telemetry."""
    cs = ClientShardChunnel(backends=tuple(backends))
    sr = ServerRouterChunnel(router_addr=router_addr)
    first, second = (sr, cs) if prefer == "server" else (cs, sr)
    return make_stack(Select(first, second), AddressedTransport(ep))


class KVClient:
    """Issues requests through a (reconfigurable) routing stack."""

    def __init__(self, fabric: Fabric, addr: str, handle):
        self.ep = fabric.register(addr) if isinstance(addr, str) else addr
        self.addr = self.ep.addr
        self.handle = handle  # ConnHandle over a routing stack
        self._rid = itertools.count()

    def request(self, op: str, key: str, val=None, timeout: float = 2.0):
        rid = next(self._rid)
        tel = getattr(self.handle, "telemetry", None)
        t0 = time.perf_counter()
        self.handle.send([{"op": op, "key": key, "val": val, "rid": rid,
                           "reply_to": self.addr}])
        buf = [None]
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            n = self.handle.recv(buf, timeout=0.05)
            if n and isinstance(buf[0], dict) and buf[0].get("rid") == rid:
                lat = time.perf_counter() - t0
                if tel is not None:
                    tel.record_rtt(lat)
                return buf[0], lat
        if tel is not None:
            tel.record_rtt(timeout)  # timeouts must drag p95 up, not vanish
        raise TimeoutError(f"kv {op} {key}")
