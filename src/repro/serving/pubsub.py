"""In-process pub/sub services with contrasting latency/ordering/cost models
(paper §7.2): the substrate under the pub/sub chunnel Select.

  KafkaBroker   self-hosted: low per-message latency, always ordered,
                fixed hourly cost, capacity-limited (queueing above rate).
  CloudPubSub   managed: higher base latency, per-message cost, elastic.
  SQSBroker     managed: ordered OR best-effort mode (cheaper + faster
                unordered — receive-side ordering then becomes the client's
                job, the Fig. 5 reconfiguration).
"""
from __future__ import annotations

import heapq
import itertools
import random
import threading
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.capability import CapabilitySet
from repro.core.chunnel import Chunnel, Datapath, WireType

MSG = WireType.of("pubsub-msg")


@dataclass
class BrokerModel:
    name: str
    base_latency_s: float
    per_msg_cost: float  # $ per message
    fixed_cost_per_h: float  # $ per hour (self-hosted)
    ordered: bool
    capacity_mps: float = 1e9  # messages/sec before queueing
    jitter_s: float = 0.0


KAFKA = BrokerModel("kafka", base_latency_s=0.0006, per_msg_cost=0.0,
                    fixed_cost_per_h=1.50, ordered=True, capacity_mps=50_000)
GCP_PUBSUB = BrokerModel("gcp-pubsub", base_latency_s=0.004, per_msg_cost=4e-8,
                         fixed_cost_per_h=0.0, ordered=True, jitter_s=0.002)
SQS_ORDERED = BrokerModel("sqs-fifo", base_latency_s=0.006, per_msg_cost=5e-7,
                          fixed_cost_per_h=0.0, ordered=True, jitter_s=0.002)
SQS_BEST_EFFORT = BrokerModel("sqs", base_latency_s=0.0022, per_msg_cost=4e-7,
                              fixed_cost_per_h=0.0, ordered=False, jitter_s=0.0015)


class Broker:
    """Topic-based broker honoring a BrokerModel."""

    def __init__(self, model: BrokerModel, seed: int = 0):
        self.model = model
        self._subs: Dict[str, List[Callable[[dict], None]]] = defaultdict(list)
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._last_deliver: Dict[str, float] = defaultdict(float)
        self.published = 0
        self.cost = 0.0
        self._seq = itertools.count()

    def subscribe(self, topic: str, fn: Callable[[dict], None]) -> None:
        with self._lock:
            self._subs[topic].append(fn)

    def unsubscribe_all(self, topic: str) -> None:
        with self._lock:
            self._subs[topic] = []

    def n_subscribers(self, topic: str) -> int:
        return len(self._subs[topic])

    def publish(self, topic: str, msg: dict) -> None:
        self.publish_batch(topic, (msg,))

    def publish_batch(self, topic: str, msgs) -> None:
        """Batched publish: one lock acquisition and one shared latency draw
        for the whole batch; per-message capacity spacing and (for unordered
        models) per-message reorder jitter are preserved. Messages without
        reorder jitter deliver together when the batch clears the capacity
        pipe — for a single message this matches the per-message model
        exactly."""
        msgs = list(msgs)
        if not msgs:
            return
        m = self.model
        now = time.monotonic()
        with self._lock:
            self.published += len(msgs)
            self.cost += m.per_msg_cost * len(msgs)
            seqs = [next(self._seq) for _ in msgs]
            delay = m.base_latency_s + (self._rng.random() * m.jitter_s)
            # capacity queueing: deliveries serialize at 1/capacity spacing;
            # the batch occupies len(msgs) slots in the pipe
            earliest = max(now + delay,
                           self._last_deliver[topic] + 1.0 / m.capacity_mps)
            done = earliest + (len(msgs) - 1) / m.capacity_mps
            self._last_deliver[topic] = done
            subs = list(self._subs[topic])
            # best-effort: occasional per-message reorder via extra delay
            extra = [m.base_latency_s * self._rng.random() * 2
                     if (not m.ordered and self._rng.random() < 0.3) else 0.0
                     for _ in msgs]
        wires = [dict(msg, _broker_seq=s) for msg, s in zip(msgs, seqs)]
        main = [w for w, e in zip(wires, extra) if e == 0.0]

        def deliver(batch):
            for w in batch:
                for fn in subs:
                    fn(dict(w))

        if main:
            t = threading.Timer(max(0.0, done - time.monotonic()), deliver, args=(main,))
            t.daemon = True
            t.start()
        for w, e in zip(wires, extra):
            if e > 0.0:
                t = threading.Timer(max(0.0, done + e - time.monotonic()),
                                    deliver, args=([w],))
                t.daemon = True
                t.start()


# ---------------------------------------------------------------------------
# Chunnels
# ---------------------------------------------------------------------------


class PubSubChunnel(Chunnel):
    """Publish/subscribe over a broker; exact-match capability per service."""

    upper_type = MSG
    lower_type = WireType.of("unit")
    multilateral = True

    def __init__(self, broker: Broker, topic: str):
        self.broker = broker
        self.topic = topic

    @property
    def name(self):
        return f"PubSub[{self.broker.model.name}]"

    def capabilities(self):
        return CapabilitySet.exact(f"pubsub:{self.broker.model.name}")

    def connect_wrap(self, inner):
        assert inner is None
        return _PubSubDP(self.broker, self.topic)


class _PubSubDP(Datapath):
    def __init__(self, broker: Broker, topic: str):
        self.broker = broker
        self.topic = topic
        self._inbox: List[dict] = []
        self._cv = threading.Condition()
        broker.subscribe(topic, self._on_msg)

    def _on_msg(self, m: dict):
        with self._cv:
            self._inbox.append(m)
            self._cv.notify_all()

    def send(self, msgs):
        self.broker.publish_batch(self.topic, msgs)

    def recv(self, buf, timeout=None):
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while not self._inbox:
                t = None if deadline is None else deadline - time.monotonic()
                if t is not None and t <= 0:
                    return 0
                self._cv.wait(timeout=t)
            n = min(len(buf), len(self._inbox))
            buf[:n] = self._inbox[:n]
            del self._inbox[:n]
            return n


class ReceiveSideOrdering(Chunnel):
    """Reorder best-effort deliveries at the receiver using sender sequence
    numbers (valid only with a single consumer — the Fig. 5 scenario)."""

    upper_type = MSG
    lower_type = MSG
    multilateral = True  # switching to service-side ordering needs agreement

    def __init__(self, groups: int = 1):
        self.groups = groups

    @property
    def name(self):
        return "ReceiveSideOrdering"

    def capabilities(self):
        return CapabilitySet.exact("order:receive-side")

    def connect_wrap(self, inner):
        return _ReorderDP(inner, self.groups)


class _ReorderDP(Datapath):
    def __init__(self, inner, groups):
        self.inner = inner
        self.groups = groups
        self._next = defaultdict(int)
        self._held: Dict[int, dict] = {}
        self._seq = defaultdict(int)

    def send(self, msgs):
        out = []
        for m in msgs:
            m = dict(m)
            g = m.get("group", 0)
            m["_order_seq"] = self._seq[g]
            self._seq[g] += 1
            out.append(m)
        self.inner.send(out)

    def _release(self, buf, n_out):
        progress = True
        while progress and n_out < len(buf):
            progress = False
            for (g, s) in sorted(self._held):
                if s == self._next[g] and n_out < len(buf):
                    buf[n_out] = self._held.pop((g, s))
                    self._next[g] += 1
                    n_out += 1
                    progress = True
        return n_out

    def recv(self, buf, timeout=None):
        # release already-reordered messages first; only block on the inner
        # datapath when nothing is releasable
        n_out = self._release(buf, 0)
        tmp: List[Optional[dict]] = [None] * max(len(buf), 8)
        while n_out < len(buf):
            got = self.inner.recv(tmp, 0.0 if n_out else timeout)
            if not got:
                break
            for k in range(got):  # hold arrivals until their turn
                m = tmp[k]
                g = m.get("group", 0)
                self._held[(g, m.get("_order_seq", 0))] = m
            n_out = self._release(buf, n_out)
            if n_out == 0:
                # keep draining whatever is queued without blocking
                timeout = 0.02
        return n_out


class ServiceOrdering(Chunnel):
    """Identity marker: ordering delegated to the (FIFO) service."""

    upper_type = MSG
    lower_type = MSG
    multilateral = True

    @property
    def name(self):
        return "ServiceOrdering"

    def capabilities(self):
        return CapabilitySet.exact("order:service")

    def connect_wrap(self, inner):
        return _PassDP(inner)


class _PassDP(Datapath):
    def __init__(self, inner):
        self.inner = inner

    def send(self, msgs):
        self.inner.send(msgs)

    def recv(self, buf, timeout=None):
        return self.inner.recv(buf, timeout)
