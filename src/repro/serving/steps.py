"""Serve-step builders: prefill and decode with production shardings."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, ShardingConfig
from repro.models.registry import Model
from repro.models.sharding import batch_axes, cache_spec_for, data_spec


def cache_shardings(cache_specs: Any, cfg: ModelConfig, mesh, sh: ShardingConfig):
    """Per-leaf NamedShardings for a cache pytree (KV leaves + SSM state)."""
    axes = batch_axes(mesh)
    b_ax = axes if len(axes) > 1 else (axes[0] if axes else None)
    n_batch = 1
    for a in axes:
        n_batch *= mesh.shape[a]
    m = mesh.shape.get("model", 1)

    def spec(path, leaf) -> P:
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        leafname = names[-1] if names else ""
        shape = leaf.shape
        if leafname in ("k", "v", "xk", "xv") and len(shape) >= 4:
            return cache_spec_for(shape, cfg, mesh, sh)
        bspec = b_ax if (len(shape) > 0 and shape[0] % max(n_batch, 1) == 0
                         and len(shape) >= 2) else None
        if leafname == "ssm_h":  # (B, d_in, N)
            ok = shape[1] % m == 0
            return P(bspec, "model" if ok else None, None)
        if leafname == "ssm_conv":  # (B, K-1, d_in)
            ok = shape[2] % m == 0
            return P(bspec, None, "model" if ok else None)
        if leafname == "C" and len(shape) == 4:  # mLSTM (B,H,hd,hd)
            ok = shape[2] % m == 0
            return P(bspec, None, "model" if ok else None, None)
        if leafname == "n" and len(shape) == 3:  # (B,H,hd)
            ok = shape[2] % m == 0
            return P(bspec, None, "model" if ok else None)
        if len(shape) == 2:  # sLSTM c/n/h (B,D)
            ok = shape[1] % m == 0
            return P(bspec, "model" if ok else None)
        return P()

    flat = jax.tree_util.tree_flatten_with_path(cache_specs)[0]
    treedef = jax.tree.structure(cache_specs)
    return jax.tree.unflatten(
        treedef, [NamedSharding(mesh, spec(p, l)) for p, l in flat])


def jit_prefill(model: Model, mesh, sh: ShardingConfig, batch_specs: dict):
    ns = lambda s: NamedSharding(mesh, s)
    param_sh = jax.tree.map(ns, model.param_specs(sh))
    batch_sh = {k: ns(data_spec(v.shape, mesh)) for k, v in batch_specs.items()}
    return jax.jit(
        lambda p, b: model.prefill(p, b),
        in_shardings=(param_sh, batch_sh),
    )


def jit_decode(model: Model, mesh, sh: ShardingConfig, batch_specs: dict,
               cache_specs: Any, donate_cache: bool = True):
    ns = lambda s: NamedSharding(mesh, s)
    param_sh = jax.tree.map(ns, model.param_specs(sh))
    batch_sh = {k: ns(data_spec(v.shape, mesh)) for k, v in batch_specs.items()}
    cache_sh = cache_shardings(cache_specs, model.cfg, mesh, sh)
    return jax.jit(
        lambda p, c, b: model.decode(p, c, b),
        in_shardings=(param_sh, cache_sh, batch_sh),
        out_shardings=(cache_sh, None),
        donate_argnums=(1,) if donate_cache else (),
    )
