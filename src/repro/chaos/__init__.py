"""Chaos harness: seeded, schedule-driven fault injection over the fabric.

See ``repro.chaos.inject`` and docs/architecture.md §9.
"""
from .inject import (
    BLACKHOLE,
    ChaosEvent,
    ChaosInjector,
    ChaosPlan,
    VirtualClock,
    node_matches,
)

__all__ = [
    "BLACKHOLE",
    "ChaosEvent",
    "ChaosInjector",
    "ChaosPlan",
    "VirtualClock",
    "node_matches",
]
