"""Fault injection over the host fabric (ROADMAP direction 5).

A ``ChaosPlan`` is a seeded, declarative schedule of network faults —
partitions, link degradation, endpoint crash/restart, arbitrary callbacks —
expressed against *node prefixes*: an event naming node ``"b"`` hits every
endpoint whose address is ``"b"`` or starts with ``"b/"``, so a HostAgent's
``/ctrl`` and ``/resync`` endpoints go down with the agent.  Events fire
either at a schedule time relative to ``ChaosInjector.start()`` (driven by
``poll``) or on a named trigger (``fire``), which is how a scenario pauses
the 2PC coordinator *exactly* mid-commit: hang the crash on a trigger and
pull it from the commit hook.

A ``ChaosInjector`` binds a plan to a ``Fabric`` using only the control
plane (``set_link`` / ``clear_link`` / the registration hook) — the
batched data path never sees the injector.  Crashes are modeled as
blackhole isolation (loss=1.0 on every link to and from the node) rather
than endpoint unregistration, so agents keep their endpoint objects across
a crash/restart cycle, exactly like a process that freezes and thaws.
Every mutation saves the pair's previous override, so ``heal``/``restart``
restores what the pair had before (including an earlier ``degrade``);
overlapping faults on the same pair must therefore heal LIFO.  A
registration hook re-applies active faults to endpoints that appear
mid-fault, so a crashed node cannot "escape" by registering a new address.

Determinism: the plan's schedule is fixed up front (``churn`` draws its
victims from the plan's seeded RNG at build time), and ``poll``/``start``
accept an explicit ``now`` so tests can drive the whole schedule on a
``VirtualClock``.
"""
from __future__ import annotations

import bisect
import itertools
import threading
import time
import random
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.fabric import Fabric, LinkModel
from repro.obs.trace import TRACER

#: total isolation — applied per-pair for partitions and crashes
BLACKHOLE = LinkModel(latency_s=0.0, jitter_s=0.0, loss=1.0)

Nodes = Union[str, Sequence[str]]


def _as_nodes(nodes: Nodes) -> Tuple[str, ...]:
    if isinstance(nodes, str):
        return (nodes,)
    return tuple(nodes)


def node_matches(addr: str, nodes: Sequence[str]) -> bool:
    """Prefix match: node "b" owns endpoint "b" and every "b/..." child."""
    for n in nodes:
        if addr == n or addr.startswith(n + "/"):
            return True
    return False


@dataclass(frozen=True)
class ChaosEvent:
    """One scheduled fault. ``at_s`` is relative to ``ChaosInjector.start``;
    ``on`` names a trigger instead. ``target`` (heal/restart only) is the
    label of the event to undo. ``for_s`` auto-schedules the heal."""

    kind: str                     # partition | degrade | crash | heal | call
    label: str
    at_s: Optional[float] = None
    on: Optional[str] = None
    a: Tuple[str, ...] = ()
    b: Tuple[str, ...] = ()
    link: Optional[LinkModel] = None
    fn: Optional[Callable[[], None]] = None
    symmetric: bool = True
    target: Optional[str] = None
    for_s: Optional[float] = None


class ChaosPlan:
    """Builder for a deterministic fault schedule.

    Every builder method returns the event's label (auto-generated when not
    given) so later ``heal``/``restart`` calls can reference it.  Exactly one
    of ``at`` (seconds after injector start) or ``on`` (trigger name) must be
    set per event.
    """

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.rng = random.Random(seed)
        self.events: List[ChaosEvent] = []
        self._counter = itertools.count(1)

    def _add(self, ev: ChaosEvent) -> str:
        if (ev.at_s is None) == (ev.on is None):
            raise ValueError(f"{ev.kind} {ev.label!r}: exactly one of at/on")
        self.events.append(ev)
        return ev.label

    def _label(self, kind: str, label: Optional[str]) -> str:
        return label if label is not None else f"{kind}-{next(self._counter)}"

    def partition(self, a: Nodes, b: Nodes, *, at: Optional[float] = None,
                  on: Optional[str] = None, label: Optional[str] = None,
                  for_s: Optional[float] = None) -> str:
        """Blackhole every link crossing the (a, b) cut, both directions."""
        return self._add(ChaosEvent(
            kind="partition", label=self._label("partition", label),
            at_s=at, on=on, a=_as_nodes(a), b=_as_nodes(b), for_s=for_s))

    def degrade(self, a: Nodes, b: Nodes, link: LinkModel, *,
                at: Optional[float] = None, on: Optional[str] = None,
                label: Optional[str] = None, symmetric: bool = True,
                for_s: Optional[float] = None) -> str:
        """Override every (a, b)-crossing link with ``link`` (WAN weather)."""
        return self._add(ChaosEvent(
            kind="degrade", label=self._label("degrade", label),
            at_s=at, on=on, a=_as_nodes(a), b=_as_nodes(b), link=link,
            symmetric=symmetric, for_s=for_s))

    def crash(self, node: Nodes, *, at: Optional[float] = None,
              on: Optional[str] = None, label: Optional[str] = None,
              for_s: Optional[float] = None) -> str:
        """Isolate a node (and all its child endpoints) from everyone else."""
        return self._add(ChaosEvent(
            kind="crash", label=self._label("crash", label),
            at_s=at, on=on, a=_as_nodes(node), for_s=for_s))

    def heal(self, target_label: str, *, at: Optional[float] = None,
             on: Optional[str] = None) -> str:
        """Undo a previously applied event, restoring saved link state."""
        return self._add(ChaosEvent(
            kind="heal", label=self._label("heal", None),
            at_s=at, on=on, target=target_label))

    def restart(self, target_label: str, *, at: Optional[float] = None,
                on: Optional[str] = None) -> str:
        """Bring a crashed node back (alias of ``heal`` for crash labels)."""
        return self.heal(target_label, at=at, on=on)

    def call(self, fn: Callable[[], None], *, at: Optional[float] = None,
             on: Optional[str] = None, label: Optional[str] = None) -> str:
        """Run an arbitrary callback at a schedule point (e.g. stop a member's
        poll loop to model a process hang the fabric can't express)."""
        return self._add(ChaosEvent(
            kind="call", label=self._label("call", label), at_s=at, on=on,
            fn=fn))

    def churn(self, nodes: Sequence[str], *, start_s: float, period_s: float,
              down_s: float, rounds: int) -> List[str]:
        """Seeded rolling churn: every ``period_s`` one plan-RNG-chosen node
        crashes for ``down_s`` then restarts. Same seed ⇒ same victims."""
        if down_s >= period_s:
            raise ValueError("down_s must be < period_s (one victim at a time)")
        labels: List[str] = []
        t = start_s
        for r in range(rounds):
            victim = self.rng.choice(list(nodes))
            lab = self.crash(victim, at=t, label=f"churn{r + 1}-{victim}",
                             for_s=down_s)
            labels.append(lab)
            t += period_s
        return labels


class ChaosInjector:
    """Applies a ``ChaosPlan`` to a ``Fabric``: timed events via ``poll``
    (driver-pumped, virtual-time friendly), trigger events via ``fire``.
    ``stop()`` heals everything still active (LIFO) and unhooks."""

    def __init__(self, fabric: Fabric, plan: ChaosPlan):
        self.fabric = fabric
        self.plan = plan
        self._lock = threading.Lock()
        self._t0: Optional[float] = None
        # timed queue kept sorted by (at_s, insertion order)
        timed = [ev for ev in plan.events if ev.at_s is not None]
        self._timed: List[Tuple[float, int, ChaosEvent]] = sorted(
            (ev.at_s, i, ev) for i, ev in enumerate(timed))
        self._next_ord = itertools.count(len(plan.events))
        self._triggers: Dict[str, List[ChaosEvent]] = {}
        for ev in plan.events:
            if ev.on is not None:
                self._triggers.setdefault(ev.on, []).append(ev)
        self._active: Dict[str, ChaosEvent] = {}  # label -> applied link event
        self._saved: Dict[str, Dict[Tuple[str, str],
                                    Optional[LinkModel]]] = {}
        self.log: List[dict] = []
        self.applied = 0
        self._hooked = False

    # -- lifecycle ---------------------------------------------------------------
    def start(self, now: Optional[float] = None) -> "ChaosInjector":
        with self._lock:
            if self._t0 is not None:
                raise RuntimeError("injector already started")
            self._t0 = time.monotonic() if now is None else now
            self._hooked = True
        self.fabric.add_register_hook(self._on_register)
        return self

    def stop(self) -> None:
        """Heal every active fault (LIFO) and detach from the fabric."""
        with self._lock:
            labels = list(reversed(list(self._active)))
            hooked, self._hooked = self._hooked, False
        for lab in labels:
            self._apply(ChaosEvent(kind="heal", label=f"stop:{lab}",
                                   target=lab), t=None)
        if hooked:
            self.fabric.remove_register_hook(self._on_register)

    def active_labels(self) -> List[str]:
        with self._lock:
            return list(self._active)

    # -- driving -----------------------------------------------------------------
    def poll(self, now: Optional[float] = None) -> int:
        """Apply every timed event whose at_s has passed; returns the count.
        Pass ``now`` explicitly (e.g. a VirtualClock reading) for virtual
        time; otherwise ``time.monotonic()`` is used."""
        with self._lock:
            if self._t0 is None:
                raise RuntimeError("injector not started")
            t = (time.monotonic() if now is None else now) - self._t0
            due: List[ChaosEvent] = []
            while self._timed and self._timed[0][0] <= t:
                due.append(self._timed.pop(0)[2])
        for ev in due:
            self._apply(ev, t)
        return len(due)

    def fire(self, trigger: str) -> int:
        """Apply every event hung on ``trigger`` immediately."""
        with self._lock:
            if self._t0 is None:
                raise RuntimeError("injector not started")
            t = time.monotonic() - self._t0
            due = self._triggers.pop(trigger, [])
        for ev in due:
            self._apply(ev, t)
        return len(due)

    # -- application -------------------------------------------------------------
    def _apply(self, ev: ChaosEvent, t: Optional[float]) -> None:
        if ev.kind == "call":
            fn = ev.fn
            if fn is not None:
                fn()  # user callback: never under the injector lock
            self._record(ev, t)
            return
        if ev.kind == "heal":
            restored = self._heal(ev.target)
            self._record(ev, t, restored=restored, target=ev.target)
            return
        with self._lock:
            n_pairs = self._apply_link_event(ev)
            if ev.for_s is not None and t is not None:
                heal = ChaosEvent(kind="heal", label=f"autoheal:{ev.label}",
                                  at_s=t + ev.for_s, target=ev.label)
                bisect.insort(self._timed,
                              (heal.at_s, next(self._next_ord), heal))
        self._record(ev, t, pairs=n_pairs)

    def _apply_link_event(self, ev: ChaosEvent) -> int:
        """Under self._lock: blackhole/degrade every crossing pair, saving the
        previous override of each pair the first time this label touches it."""
        eps = self.fabric.endpoints()
        pairs = _event_pairs(ev, eps)
        saved = self._saved.setdefault(ev.label, {})
        model = ev.link if ev.kind == "degrade" else BLACKHOLE
        for s, d in pairs:
            if (s, d) not in saved:
                saved[(s, d)] = self.fabric.link_override(s, d)
            self.fabric.set_link(s, d, model)
        self._active[ev.label] = ev  # lint: allow[unguarded-attr] documented contract ("Under self._lock"): the only caller, _apply, holds self._lock around this call
        return len(pairs)

    def _heal(self, label: Optional[str]) -> int:
        with self._lock:
            self._active.pop(label, None)
            saved = self._saved.pop(label, None)
            n = 0
            if saved:
                for (s, d), prev in saved.items():
                    if prev is None:
                        self.fabric.clear_link(s, d)
                    else:
                        self.fabric.set_link(s, d, prev)
                    n += 1
            return n

    def _on_register(self, addr: str) -> None:
        """Fabric registration hook: extend active faults to new endpoints so
        a node can't escape its partition by registering a fresh address."""
        with self._lock:
            for label, ev in self._active.items():
                eps = self.fabric.endpoints()
                pairs = [p for p in _event_pairs(ev, eps) if addr in p]
                if not pairs:
                    continue
                saved = self._saved.setdefault(label, {})
                model = ev.link if ev.kind == "degrade" else BLACKHOLE
                for s, d in pairs:
                    if (s, d) not in saved:
                        saved[(s, d)] = self.fabric.link_override(s, d)
                    self.fabric.set_link(s, d, model)

    def _record(self, ev: ChaosEvent, t: Optional[float], **extra) -> None:
        with self._lock:
            self.applied += 1
            entry = {"t_s": None if t is None else round(t, 6),
                     "kind": ev.kind, "label": ev.label}
            entry.update(extra)
            self.log.append(entry)
        if TRACER.enabled:
            # outside self._lock: fault apply/heal instants land in the SAME
            # timeline as the controller/2PC spans they perturb
            TRACER.event(f"chaos.{ev.kind}", attrs=dict(entry))


def _event_pairs(ev: ChaosEvent,
                 eps: Sequence[str]) -> List[Tuple[str, str]]:
    """Directed (src, dst) pairs an event overrides, given current endpoints."""
    if ev.kind == "crash":
        ours = [e for e in eps if node_matches(e, ev.a)]
        others = [e for e in eps if not node_matches(e, ev.a)]
        pairs = [(x, y) for x in ours for y in others]
    else:
        pa = [e for e in eps if node_matches(e, ev.a)]
        pb = [e for e in eps if node_matches(e, ev.b)]
        pairs = [(x, y) for x in pa for y in pb if x != y]
    if ev.symmetric:
        pairs = pairs + [(d, s) for s, d in pairs]
    seen, out = set(), []
    for p in pairs:
        if p not in seen:
            seen.add(p)
            out.append(p)
    return out


class VirtualClock:
    """Deterministic stand-in for ``time.monotonic`` in schedule tests:
    ``poll(now=clock())`` after ``clock.advance(dt)`` replays a plan exactly,
    independent of CI machine speed."""

    def __init__(self, t: float = 0.0):
        self._t = t
        self._lock = threading.Lock()

    def __call__(self) -> float:
        with self._lock:
            return self._t

    def advance(self, dt: float) -> float:
        with self._lock:
            self._t += dt
            return self._t
