from repro.comm.chunnels import (
    GradCompressed,
    GradHierCompressed,
    GradHierarchical,
    GradLocalSGD,
    GradPsum,
    GradRing,
    GradXla,
    StepChunnel,
    apply_grad_stack,
    init_grad_states,
    make_transport,
    stack_manual_axes,
)
from repro.comm.kvshard import KVHeadSharded, KVSeqSharded, make_seq_sharded_decode, pick_kv_chunnel
from repro.comm.moe_dispatch import MoEDispatch

__all__ = [
    "GradCompressed", "GradHierCompressed", "GradHierarchical", "GradLocalSGD",
    "GradPsum", "GradRing", "GradXla", "KVHeadSharded", "KVSeqSharded",
    "MoEDispatch", "StepChunnel", "apply_grad_stack", "init_grad_states",
    "make_seq_sharded_decode", "make_transport", "pick_kv_chunnel", "stack_manual_axes",
]
