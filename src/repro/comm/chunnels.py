"""Step chunnels: Bertha chunnels whose datapath is the jitted step dataflow.

On a TPU cluster EVERY collective chunnel is multilateral: hosts must compile
the identical SPMD program or the job deadlocks at the first mismatched
collective — which is exactly the incompatibility Bertha's negotiation exists
to prevent (DESIGN.md §2). The exact-match capability labels below are what
the host agents negotiate before compiling.

The gradient-transport Select (paper Fig. 1's Kernel-vs-DPDK analogue):

    Select(GradXla(), GradHierarchical(), GradRing(), GradCompressed())

GradXla delegates the whole schedule to the XLA partitioner (paper-faithful
default); the others take manual control of the pod/DCN tier via shard_map.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from repro.comm import collectives, compress
from repro.core.capability import CapabilitySet
from repro.core.chunnel import Chunnel, Datapath, WireType
from repro.core.cost import CostModel

GRADS_F32 = WireType.of("grads", dtype="f32")
UNIT = WireType.of("unit")


#: every step-transport switch re-jits the step function — that blip dominates
#: the mechanism cost and is identical across transports, so the scorer's
#: switch-aversion for this plane is uniform (see repro.core.cost)
REJIT_BLIP_S = 2.0


# ---------------------------------------------------------------------------
# Mesh-aware cost calibration (ROADMAP "Mesh-aware cost models")
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CostCalibration:
    """Live overrides for the static transport cost annotations.

    n_fast            the LIVE fast-axis width — hierarchy credit in
                      ``dcn_bytes_factor`` divides by this instead of the
                      static ``StepChunnel.NOMINAL_FAST`` guess
    dcn_bytes_per_s   measured slow-tier link bandwidth (e.g. from
                      ``repro.fleet.signals.LinkBandwidthSignal``) — feeds
                      ``calibrated_objective``'s byte→seconds normalizer
    """

    n_fast: Optional[int] = None
    dcn_bytes_per_s: Optional[float] = None


_CALIBRATION = CostCalibration()


def calibrate_cost_models(*, mesh=None, fast_axis: str = "data",
                          link_bytes_per_s: Optional[float] = None,
                          signal=None) -> CostCalibration:
    """Derive the transport cost models' terms from the live mesh shape and a
    measured link bandwidth, instead of the static ``NOMINAL_FAST``
    annotation. Process-wide (the mesh is process-wide too): the trainer
    calls this at construction; ``reset_cost_calibration`` restores the
    static annotations (tests). ``signal`` is anything whose ``read()``
    yields ``ext.link_bytes_per_s`` (``LinkBandwidthSignal``); an explicit
    ``link_bytes_per_s`` wins over it. Fields not derivable from THIS call's
    arguments keep their current calibration (so the trainer installing its
    mesh width does not wipe a previously measured bandwidth)."""
    global _CALIBRATION
    n_fast = _CALIBRATION.n_fast
    if mesh is not None and fast_axis in getattr(mesh, "axis_names", ()):
        n_fast = int(mesh.shape[fast_axis])
    bw = link_bytes_per_s
    if bw is None and signal is not None:
        bw = (signal.read() or {}).get("ext.link_bytes_per_s")
    if bw is None:
        bw = _CALIBRATION.dcn_bytes_per_s
    _CALIBRATION = CostCalibration(n_fast=n_fast, dcn_bytes_per_s=bw)
    return _CALIBRATION


def cost_calibration() -> CostCalibration:
    return _CALIBRATION


def reset_cost_calibration() -> None:
    global _CALIBRATION
    _CALIBRATION = CostCalibration()


def calibrated_objective(base):
    """``base`` (a ``repro.core.cost.Objective``) with its byte→seconds
    normalizer derived from the measured link bandwidth, when one has been
    calibrated — so byte-weighted scoring reflects the link the fleet
    actually runs on, not the nominal 1 GB/s default."""
    import dataclasses

    bw = _CALIBRATION.dcn_bytes_per_s
    if not bw:
        return base
    return dataclasses.replace(base, dcn_s_per_byte=1.0 / bw,
                               name=f"{base.name}@measured")


class StepChunnel(Chunnel):
    """A chunnel applied to pytrees inside the jitted step function.

    connect_wrap composes at *trace time* — the compiled program carries no
    dispatch overhead (the Rust-monomorphization property, verified in
    benchmarks/bench_overhead.py by HLO comparison).
    """

    multilateral = True  # SPMD: all hosts must agree
    upper_type = GRADS_F32
    lower_type = UNIT

    #: mesh axes this chunnel needs manual (shard_map) control over
    manual_axes: tuple = ()

    #: nominal fast-axis width assumed by cost models that divide DCN bytes by
    #: |fast| when NO live calibration is installed — the fallback for code
    #: that scores transports without a mesh in hand (coarse on purpose; the
    #: scorer only needs ordering). ``calibrate_cost_models(mesh=...)``
    #: replaces it with the live axis width.
    NOMINAL_FAST = 4

    def fast_width(self) -> int:
        """Fast-axis width the cost model divides DCN bytes by: the LIVE
        calibrated width when ``calibrate_cost_models`` has seen a mesh,
        else the static ``NOMINAL_FAST`` annotation."""
        cal = cost_calibration()
        return cal.n_fast if cal.n_fast else self.NOMINAL_FAST

    #: False for transports that trade gradient freshness for communication
    #: (localsgd-style): their cost models honestly win the comm-cost contest,
    #: so scoring policies must not treat them as steady-state candidates —
    #: only an explicit mitigation rule may select them
    exact_sync = True

    def init_state(self, grads_shape):
        return ()

    def apply(self, tree, state, ctx: dict):
        raise NotImplementedError

    def connect_wrap(self, inner: Optional[Datapath]) -> Datapath:
        return _StepDatapath(self, inner)


class _StepDatapath(Datapath):
    def __init__(self, ch: StepChunnel, inner: Optional[Datapath]):
        self.ch = ch
        self.inner = inner

    def send(self, msgs):
        raise RuntimeError("step chunnels run inside jit via apply(), not send()")

    recv = send


def apply_grad_stack(chunnels, tree, states, ctx):
    """Fold grads through the stack top-down; returns (tree, new_states)."""
    new_states = []
    for ch, st in zip(chunnels, states):
        tree, st = ch.apply(tree, st, ctx)
        new_states.append(st)
    return tree, tuple(new_states)


def stack_manual_axes(chunnels) -> set:
    out = set()
    for ch in chunnels:
        out |= set(getattr(ch, "manual_axes", ()))
    return out


def init_grad_states(chunnels, grads_shape):
    return tuple(ch.init_state(grads_shape) for ch in chunnels)


# ---------------------------------------------------------------------------
# Transports
# ---------------------------------------------------------------------------


@dataclass
class GradXla(StepChunnel):
    """Delegate gradient sync to the XLA partitioner (the 'kernel stack')."""

    axis: str = "pod"
    manual_axes = ()

    @property
    def name(self):
        return "GradXla"

    def capabilities(self):
        return CapabilitySet.exact("wire:f32").union_(
            CapabilitySet.compose("transport:xla"))

    def cost_model(self):
        # baseline: one fused f32 AR per step, schedule fully fused by XLA
        return CostModel(op_latency_s=3e-3,
                         dcn_bytes_per_byte=collectives.dcn_bytes_factor("xla"),
                         switch_blip_s=REJIT_BLIP_S)

    def apply(self, tree, state, ctx):
        return tree, state  # XLA inserts the collectives itself


@dataclass
class GradPsum(StepChunnel):
    """Explicit psum over the slow axis (XLA-native AR, manual placement)."""

    axis: str = "pod"

    def __post_init__(self):
        self.manual_axes = (self.axis,)

    @property
    def name(self):
        return "GradPsum"

    def capabilities(self):
        return CapabilitySet.exact("wire:f32", f"transport:psum@{self.axis}")

    def cost_model(self):
        return CostModel(op_latency_s=3e-3,
                         dcn_bytes_per_byte=collectives.dcn_bytes_factor("psum"),
                         switch_blip_s=REJIT_BLIP_S)

    def apply(self, tree, state, ctx):
        return collectives.pmean_tree(tree, self.axis), state


@dataclass
class GradRing(StepChunnel):
    """Bidirectional-ring RS+AG via collective-permutes (explicit schedule)."""

    axis: str = "pod"

    def __post_init__(self):
        self.manual_axes = (self.axis,)

    @property
    def name(self):
        return "GradRing"

    def capabilities(self):
        return CapabilitySet.exact("wire:f32", f"transport:ring@{self.axis}")

    def cost_model(self):
        # same DCN bytes as psum, but 2(n-1) dependent permute steps instead
        # of one fused AR: higher per-step latency on real links
        return CostModel(op_latency_s=4e-3,
                         dcn_bytes_per_byte=collectives.dcn_bytes_factor("ring"),
                         switch_blip_s=REJIT_BLIP_S)

    def apply(self, tree, state, ctx):
        n = ctx["mesh"].shape[self.axis]
        out = collectives.ring_tree(tree, self.axis)
        return jax.tree.map(lambda g: g / n, out), state


@dataclass
class GradHierarchical(StepChunnel):
    """RS(fast/ICI) -> AR(slow/DCN) -> AG(fast): per-chip DCN bytes / |fast|.

    INCOMPATIBLE with FSDP over the fast axis: taking 'data' manual replicates
    the FSDP-sharded params inside the region (measured: 2TB/device on the
    235B cell — EXPERIMENTS.md §Perf refuted-hypothesis log). With FSDP the
    XLA/psum pod transport already sends only each chip's 1/|data| gradient
    shard over DCN, i.e. FSDP+psum IS the hierarchical schedule. Negotiation
    enforces this via the layout:fsdp exact capability below.
    """

    fast_axis: str = "data"
    slow_axis: str = "pod"

    def __post_init__(self):
        self.manual_axes = (self.fast_axis, self.slow_axis)

    @property
    def name(self):
        return "GradHierarchical"

    def capabilities(self):
        # exact 'layout:noshard@fast' conflicts with FSDP stacks (which carry
        # 'layout:fsdp@data'): Bertha's negotiation rejects the combination.
        return CapabilitySet.exact(
            "wire:f32", f"transport:hier@{self.fast_axis}+{self.slow_axis}",
            f"layout:noshard@{self.fast_axis}")

    def cost_model(self):
        return CostModel(
            op_latency_s=2e-3,
            dcn_bytes_per_byte=collectives.dcn_bytes_factor(
                "hierarchical", n_fast=self.fast_width()),
            switch_blip_s=REJIT_BLIP_S)

    def apply(self, tree, state, ctx):
        n = ctx["mesh"].shape[self.slow_axis] * ctx["mesh"].shape[self.fast_axis]
        out = collectives.hierarchical_tree(tree, self.fast_axis, self.slow_axis)
        return jax.tree.map(lambda g: g / n, out), state


@dataclass
class GradCompressed(StepChunnel):
    """int8 block-quantized DCN wire format + error feedback (multilateral:
    both ends must speak wire:int8-blockq — the serialization-chunnel analogue)."""

    axis: str = "pod"
    block: int = 256
    error_feedback: bool = True
    use_kernel: bool = False

    def __post_init__(self):
        self.manual_axes = (self.axis,)

    @property
    def name(self):
        return "GradCompressed"

    def capabilities(self):
        return CapabilitySet.exact(f"wire:int8-blockq{self.block}",
                                   f"transport:cag@{self.axis}")

    def cost_model(self):
        # 4x fewer DCN bytes, but quantize/dequantize compute on the fast path
        return CostModel(
            op_latency_s=2.5e-3,
            dcn_bytes_per_byte=collectives.dcn_bytes_factor(
                "compressed", wire_ratio=compress.int8_wire_ratio(self.block)),
            switch_blip_s=REJIT_BLIP_S)

    def init_state(self, grads_shape):
        if not self.error_feedback:
            return ()
        return jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), grads_shape)

    def apply(self, tree, state, ctx):
        n = ctx["mesh"].shape[self.axis]
        if self.error_feedback and state != ():
            tree = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, tree, state)
        out = collectives.compressed_tree(tree, self.axis, block=self.block,
                                          use_kernel=self.use_kernel)
        new_state = state
        if self.error_feedback and state != ():
            # residual of OUR contribution (what we failed to transmit)
            new_state = jax.tree.map(
                lambda g: compress.quantize_error(g, block=self.block), tree)
        return jax.tree.map(lambda g: g / n, out), new_state


@dataclass
class GradHierCompressed(StepChunnel):
    """Beyond-paper: hierarchical + compressed DCN tier combined."""

    fast_axis: str = "data"
    slow_axis: str = "pod"
    block: int = 256
    use_kernel: bool = False

    def __post_init__(self):
        self.manual_axes = (self.fast_axis, self.slow_axis)

    @property
    def name(self):
        return "GradHierCompressed"

    def capabilities(self):
        return CapabilitySet.exact(
            f"wire:int8-blockq{self.block}",
            f"transport:hiercag@{self.fast_axis}+{self.slow_axis}",
            f"layout:noshard@{self.fast_axis}",
        )

    def cost_model(self):
        return CostModel(
            op_latency_s=2.2e-3,
            dcn_bytes_per_byte=collectives.dcn_bytes_factor(
                "hier_compressed", n_fast=self.fast_width(),
                wire_ratio=compress.int8_wire_ratio(self.block)),
            switch_blip_s=REJIT_BLIP_S)

    def apply(self, tree, state, ctx):
        n = ctx["mesh"].shape[self.slow_axis] * ctx["mesh"].shape[self.fast_axis]
        out = collectives.hierarchical_compressed_tree(
            tree, self.fast_axis, self.slow_axis, block=self.block,
            use_kernel=self.use_kernel)
        return jax.tree.map(lambda g: g / n, out), state


@dataclass
class GradLocalSGD(StepChunnel):
    """Straggler/elasticity mitigation: sync every H steps, accumulate locally
    otherwise (async-ish DCN relief; a reconfiguration target when the runtime
    detects slow pods)."""

    axis: str = "pod"
    sync_every: int = 4
    exact_sync = False  # H-1 of H steps run on stale pod-local gradients

    def __post_init__(self):
        self.manual_axes = (self.axis,)

    @property
    def name(self):
        return "GradLocalSGD"

    def capabilities(self):
        return CapabilitySet.exact("wire:f32", f"transport:localsgd{self.sync_every}@{self.axis}")

    def cost_model(self):
        # Honest about COMMUNICATION cost only: skipping the AR on H-1 of H
        # steps genuinely is the cheapest transport on both scored dimensions.
        # The price — gradient staleness / statistical efficiency — is outside
        # the model, so scoring policies must treat localsgd as a straggler
        # MITIGATION, not a steady-state candidate (trainer_default excludes
        # the mitigation target from its scored byte-budget argmax).
        return CostModel(
            op_latency_s=1e-3,
            dcn_bytes_per_byte=collectives.dcn_bytes_factor(
                "localsgd", sync_every=self.sync_every),
            switch_blip_s=REJIT_BLIP_S)

    def init_state(self, grads_shape):
        return {"step": jnp.zeros((), jnp.int32)}

    def apply(self, tree, state, ctx):
        step = state["step"]
        do_sync = (step % self.sync_every) == self.sync_every - 1

        def sync(t):
            return collectives.pmean_tree(t, self.axis)

        out = jax.lax.cond(do_sync, sync, lambda t: t, tree)
        return out, {"step": step + 1}


TRANSPORTS = {
    "xla": GradXla,
    "psum": GradPsum,
    "ring": GradRing,
    "hierarchical": GradHierarchical,
    "compressed_int8": GradCompressed,
    "hier_compressed": GradHierCompressed,
    "localsgd": GradLocalSGD,
}


def make_transport(name: str, **kw) -> StepChunnel:
    return TRANSPORTS[name](**kw)
