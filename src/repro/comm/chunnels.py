"""Step chunnels: Bertha chunnels whose datapath is the jitted step dataflow.

On a TPU cluster EVERY collective chunnel is multilateral: hosts must compile
the identical SPMD program or the job deadlocks at the first mismatched
collective — which is exactly the incompatibility Bertha's negotiation exists
to prevent (DESIGN.md §2). The exact-match capability labels below are what
the host agents negotiate before compiling.

The gradient-transport Select (paper Fig. 1's Kernel-vs-DPDK analogue):

    Select(GradXla(), GradHierarchical(), GradRing(), GradCompressed())

GradXla delegates the whole schedule to the XLA partitioner (paper-faithful
default); the others take manual control of the pod/DCN tier via shard_map.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import collectives, compress
from repro.core.capability import CapabilitySet
from repro.core.chunnel import Chunnel, Datapath, WireType
from repro.obs.trace import NOOP_SPAN, TRACER
from repro.core.controller import (
    PolicyContext,
    Rule,
    above,
    all_of,
    below,
    register_policy,
)
from repro.core.cost import (CostModel, install_measured_costs,
                             reset_measured_costs)

GRADS_F32 = WireType.of("grads", dtype="f32")
UNIT = WireType.of("unit")


#: every step-transport switch re-jits the step function — that blip dominates
#: the mechanism cost and is identical across transports, so the scorer's
#: switch-aversion for this plane is uniform (see repro.core.cost)
REJIT_BLIP_S = 2.0


# ---------------------------------------------------------------------------
# Mesh-aware cost calibration (ROADMAP "Mesh-aware cost models")
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CostCalibration:
    """Live overrides for the static transport cost annotations.

    n_fast            the LIVE fast-axis width — hierarchy credit in
                      ``dcn_bytes_factor`` divides by this instead of the
                      static ``StepChunnel.NOMINAL_FAST`` guess
    dcn_bytes_per_s   measured slow-tier link bandwidth (e.g. from
                      ``repro.fleet.signals.LinkBandwidthSignal``) — feeds
                      ``calibrated_objective``'s byte→seconds normalizer
    """

    n_fast: Optional[int] = None
    dcn_bytes_per_s: Optional[float] = None


_CALIBRATION = CostCalibration()


def calibrate_cost_models(*, mesh=None, fast_axis: str = "data",
                          link_bytes_per_s: Optional[float] = None,
                          signal=None, measured=None) -> CostCalibration:
    """Derive the transport cost models' terms from the live mesh shape and a
    measured link bandwidth, instead of the static ``NOMINAL_FAST``
    annotation. Process-wide (the mesh is process-wide too): the trainer
    calls this at construction; ``reset_cost_calibration`` restores the
    static annotations (tests). ``signal`` is anything whose ``read()``
    yields ``ext.link_bytes_per_s`` (``LinkBandwidthSignal``); an explicit
    ``link_bytes_per_s`` wins over it. Fields not derivable from THIS call's
    arguments keep their current calibration (so the trainer installing its
    mesh width does not wipe a previously measured bandwidth).

    ``measured`` installs trace-derived per-chunnel cost overrides — either
    a ``repro.obs.calibrate.TraceCalibration`` or a plain
    ``{chunnel_name: {cost field: value}}`` dict — into the core scorer's
    measured tables (``repro.core.cost.install_measured_costs``); the full
    loop is ``calibrate_from_traces(records)``, which calls this.
    """
    global _CALIBRATION
    n_fast = _CALIBRATION.n_fast
    if mesh is not None and fast_axis in getattr(mesh, "axis_names", ()):
        n_fast = int(mesh.shape[fast_axis])
    bw = link_bytes_per_s
    if bw is None and signal is not None:
        bw = (signal.read() or {}).get("ext.link_bytes_per_s")
    if bw is None:
        bw = _CALIBRATION.dcn_bytes_per_s
    if measured is not None:
        chunnels = getattr(measured, "chunnels", measured)
        blips = getattr(measured, "stack_blips", None) or {}
        install_measured_costs(chunnels=chunnels, stack_blips=blips)
    _CALIBRATION = CostCalibration(n_fast=n_fast, dcn_bytes_per_s=bw)
    return _CALIBRATION


def cost_calibration() -> CostCalibration:
    return _CALIBRATION


def reset_cost_calibration() -> None:
    """Restore static annotations: the mesh/bandwidth calibration AND any
    trace-derived measured cost overrides."""
    global _CALIBRATION
    _CALIBRATION = CostCalibration()
    reset_measured_costs()


def calibrated_objective(base):
    """``base`` (a ``repro.core.cost.Objective``) with its byte→seconds
    normalizer derived from the measured link bandwidth, when one has been
    calibrated — so byte-weighted scoring reflects the link the fleet
    actually runs on, not the nominal 1 GB/s default."""
    import dataclasses

    bw = _CALIBRATION.dcn_bytes_per_s
    if not bw:
        return base
    return dataclasses.replace(base, dcn_s_per_byte=1.0 / bw,
                               name=f"{base.name}@measured")


class StepChunnel(Chunnel):
    """A chunnel applied to pytrees inside the jitted step function.

    connect_wrap composes at *trace time* — the compiled program carries no
    dispatch overhead (the Rust-monomorphization property, verified in
    benchmarks/bench_overhead.py by HLO comparison).
    """

    multilateral = True  # SPMD: all hosts must agree
    upper_type = GRADS_F32
    lower_type = UNIT

    #: mesh axes this chunnel needs manual (shard_map) control over
    manual_axes: tuple = ()

    #: nominal fast-axis width assumed by cost models that divide DCN bytes by
    #: |fast| when NO live calibration is installed — the fallback for code
    #: that scores transports without a mesh in hand (coarse on purpose; the
    #: scorer only needs ordering). ``calibrate_cost_models(mesh=...)``
    #: replaces it with the live axis width.
    NOMINAL_FAST = 4

    def fast_width(self) -> int:
        """Fast-axis width the cost model divides DCN bytes by: the LIVE
        calibrated width when ``calibrate_cost_models`` has seen a mesh,
        else the static ``NOMINAL_FAST`` annotation."""
        cal = cost_calibration()
        return cal.n_fast if cal.n_fast else self.NOMINAL_FAST

    #: False for transports that trade gradient freshness for communication
    #: (localsgd-style): their cost models honestly win the comm-cost contest,
    #: so scoring policies must not treat them as steady-state candidates —
    #: only an explicit mitigation rule may select them
    exact_sync = True

    def init_state(self, grads_shape):
        return ()

    def apply(self, tree, state, ctx: dict):
        raise NotImplementedError

    def connect_wrap(self, inner: Optional[Datapath]) -> Datapath:
        return _StepDatapath(self, inner)


class _StepDatapath(Datapath):
    def __init__(self, ch: StepChunnel, inner: Optional[Datapath]):
        self.ch = ch
        self.inner = inner

    def send(self, msgs):
        raise RuntimeError("step chunnels run inside jit via apply(), not send()")

    recv = send


def apply_grad_stack(chunnels, tree, states, ctx):
    """Fold grads through the stack top-down; returns (tree, new_states)."""
    new_states = []
    for ch, st in zip(chunnels, states):
        tree, st = ch.apply(tree, st, ctx)
        new_states.append(st)
    return tree, tuple(new_states)


def stack_manual_axes(chunnels) -> set:
    out = set()
    for ch in chunnels:
        out |= set(getattr(ch, "manual_axes", ()))
    return out


def init_grad_states(chunnels, grads_shape):
    return tuple(ch.init_state(grads_shape) for ch in chunnels)


# ---------------------------------------------------------------------------
# Transports
# ---------------------------------------------------------------------------


@dataclass
class GradXla(StepChunnel):
    """Delegate gradient sync to the XLA partitioner (the 'kernel stack')."""

    axis: str = "pod"
    manual_axes = ()

    @property
    def name(self):
        return "GradXla"

    def capabilities(self):
        return CapabilitySet.exact("wire:f32").union_(
            CapabilitySet.compose("transport:xla"))

    def cost_model(self):
        # baseline: one fused f32 AR per step, schedule fully fused by XLA
        return CostModel(op_latency_s=3e-3,
                         dcn_bytes_per_byte=collectives.dcn_bytes_factor("xla"),
                         switch_blip_s=REJIT_BLIP_S)

    def apply(self, tree, state, ctx):
        return tree, state  # XLA inserts the collectives itself


@dataclass
class GradPsum(StepChunnel):
    """Explicit psum over the slow axis (XLA-native AR, manual placement)."""

    axis: str = "pod"

    def __post_init__(self):
        self.manual_axes = (self.axis,)

    @property
    def name(self):
        return "GradPsum"

    def capabilities(self):
        return CapabilitySet.exact("wire:f32", f"transport:psum@{self.axis}")

    def cost_model(self):
        return CostModel(op_latency_s=3e-3,
                         dcn_bytes_per_byte=collectives.dcn_bytes_factor("psum"),
                         switch_blip_s=REJIT_BLIP_S)

    def apply(self, tree, state, ctx):
        return collectives.pmean_tree(tree, self.axis), state


@dataclass
class GradRing(StepChunnel):
    """Bidirectional-ring RS+AG via collective-permutes (explicit schedule)."""

    axis: str = "pod"

    def __post_init__(self):
        self.manual_axes = (self.axis,)

    @property
    def name(self):
        return "GradRing"

    def capabilities(self):
        return CapabilitySet.exact("wire:f32", f"transport:ring@{self.axis}")

    def cost_model(self):
        # same DCN bytes as psum, but 2(n-1) dependent permute steps instead
        # of one fused AR: higher per-step latency on real links
        return CostModel(op_latency_s=4e-3,
                         dcn_bytes_per_byte=collectives.dcn_bytes_factor("ring"),
                         switch_blip_s=REJIT_BLIP_S)

    def apply(self, tree, state, ctx):
        n = ctx["mesh"].shape[self.axis]
        out = collectives.ring_tree(tree, self.axis)
        return jax.tree.map(lambda g: g / n, out), state


@dataclass
class GradHierarchical(StepChunnel):
    """RS(fast/ICI) -> AR(slow/DCN) -> AG(fast): per-chip DCN bytes / |fast|.

    INCOMPATIBLE with FSDP over the fast axis: taking 'data' manual replicates
    the FSDP-sharded params inside the region (measured: 2TB/device on the
    235B cell — EXPERIMENTS.md §Perf refuted-hypothesis log). With FSDP the
    XLA/psum pod transport already sends only each chip's 1/|data| gradient
    shard over DCN, i.e. FSDP+psum IS the hierarchical schedule. Negotiation
    enforces this via the layout:fsdp exact capability below.
    """

    fast_axis: str = "data"
    slow_axis: str = "pod"

    def __post_init__(self):
        self.manual_axes = (self.fast_axis, self.slow_axis)

    @property
    def name(self):
        return "GradHierarchical"

    def capabilities(self):
        # exact 'layout:noshard@fast' conflicts with FSDP stacks (which carry
        # 'layout:fsdp@data'): Bertha's negotiation rejects the combination.
        return CapabilitySet.exact(
            "wire:f32", f"transport:hier@{self.fast_axis}+{self.slow_axis}",
            f"layout:noshard@{self.fast_axis}")

    def cost_model(self):
        return CostModel(
            op_latency_s=2e-3,
            dcn_bytes_per_byte=collectives.dcn_bytes_factor(
                "hierarchical", n_fast=self.fast_width()),
            switch_blip_s=REJIT_BLIP_S)

    def apply(self, tree, state, ctx):
        n = ctx["mesh"].shape[self.slow_axis] * ctx["mesh"].shape[self.fast_axis]
        out = collectives.hierarchical_tree(tree, self.fast_axis, self.slow_axis)
        return jax.tree.map(lambda g: g / n, out), state


@dataclass
class GradCompressed(StepChunnel):
    """int8 block-quantized DCN wire format + error feedback (multilateral:
    both ends must speak wire:int8-blockq — the serialization-chunnel analogue)."""

    axis: str = "pod"
    block: int = 256
    error_feedback: bool = True
    use_kernel: bool = False

    def __post_init__(self):
        self.manual_axes = (self.axis,)

    @property
    def name(self):
        return "GradCompressed"

    def capabilities(self):
        return CapabilitySet.exact(f"wire:int8-blockq{self.block}",
                                   f"transport:cag@{self.axis}")

    def cost_model(self):
        # 4x fewer DCN bytes, but quantize/dequantize compute on the fast path
        return CostModel(
            op_latency_s=2.5e-3,
            dcn_bytes_per_byte=collectives.dcn_bytes_factor(
                "compressed", wire_ratio=compress.int8_wire_ratio(self.block)),
            switch_blip_s=REJIT_BLIP_S)

    def init_state(self, grads_shape):
        if not self.error_feedback:
            return ()
        return jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), grads_shape)

    def apply(self, tree, state, ctx):
        n = ctx["mesh"].shape[self.axis]
        if self.error_feedback and state != ():
            tree = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, tree, state)
        out = collectives.compressed_tree(tree, self.axis, block=self.block,
                                          use_kernel=self.use_kernel)
        new_state = state
        if self.error_feedback and state != ():
            # residual of OUR contribution (what we failed to transmit)
            new_state = jax.tree.map(
                lambda g: compress.quantize_error(g, block=self.block), tree)
        return jax.tree.map(lambda g: g / n, out), new_state


@dataclass
class GradHierCompressed(StepChunnel):
    """Beyond-paper: hierarchical + compressed DCN tier combined."""

    fast_axis: str = "data"
    slow_axis: str = "pod"
    block: int = 256
    use_kernel: bool = False

    def __post_init__(self):
        self.manual_axes = (self.fast_axis, self.slow_axis)

    @property
    def name(self):
        return "GradHierCompressed"

    def capabilities(self):
        return CapabilitySet.exact(
            f"wire:int8-blockq{self.block}",
            f"transport:hiercag@{self.fast_axis}+{self.slow_axis}",
            f"layout:noshard@{self.fast_axis}",
        )

    def cost_model(self):
        return CostModel(
            op_latency_s=2.2e-3,
            dcn_bytes_per_byte=collectives.dcn_bytes_factor(
                "hier_compressed", n_fast=self.fast_width(),
                wire_ratio=compress.int8_wire_ratio(self.block)),
            switch_blip_s=REJIT_BLIP_S)

    def apply(self, tree, state, ctx):
        n = ctx["mesh"].shape[self.slow_axis] * ctx["mesh"].shape[self.fast_axis]
        out = collectives.hierarchical_compressed_tree(
            tree, self.fast_axis, self.slow_axis, block=self.block,
            use_kernel=self.use_kernel)
        return jax.tree.map(lambda g: g / n, out), state


@dataclass
class GradLocalSGD(StepChunnel):
    """Straggler/elasticity mitigation: sync every H steps, accumulate locally
    otherwise (async-ish DCN relief; a reconfiguration target when the runtime
    detects slow pods)."""

    axis: str = "pod"
    sync_every: int = 4
    exact_sync = False  # H-1 of H steps run on stale pod-local gradients

    def __post_init__(self):
        self.manual_axes = (self.axis,)

    @property
    def name(self):
        return "GradLocalSGD"

    def capabilities(self):
        return CapabilitySet.exact("wire:f32", f"transport:localsgd{self.sync_every}@{self.axis}")

    def cost_model(self):
        # Honest about COMMUNICATION cost only: skipping the AR on H-1 of H
        # steps genuinely is the cheapest transport on both scored dimensions.
        # The price — gradient staleness / statistical efficiency — is outside
        # the model, so scoring policies must treat localsgd as a straggler
        # MITIGATION, not a steady-state candidate (trainer_default excludes
        # the mitigation target from its scored byte-budget argmax).
        return CostModel(
            op_latency_s=1e-3,
            dcn_bytes_per_byte=collectives.dcn_bytes_factor(
                "localsgd", sync_every=self.sync_every),
            switch_blip_s=REJIT_BLIP_S)

    def init_state(self, grads_shape):
        return {"step": jnp.zeros((), jnp.int32)}

    def apply(self, tree, state, ctx):
        step = state["step"]
        do_sync = (step % self.sync_every) == self.sync_every - 1

        def sync(t):
            return collectives.pmean_tree(t, self.axis)

        out = jax.lax.cond(do_sync, sync, lambda t: t, tree)
        return out, {"step": step + 1}


TRANSPORTS = {
    "xla": GradXla,
    "psum": GradPsum,
    "ring": GradRing,
    "hierarchical": GradHierarchical,
    "compressed_int8": GradCompressed,
    "hier_compressed": GradHierCompressed,
    "localsgd": GradLocalSGD,
}


def make_transport(name: str, **kw) -> StepChunnel:
    return TRANSPORTS[name](**kw)


# ---------------------------------------------------------------------------
# WAN link layer (host plane, ROADMAP direction 5)
# ---------------------------------------------------------------------------


class WanLinkChunnel(Chunnel):
    """WAN-grade link transport: the "compressed + reliable" stack option a
    region adopts when its links turn hostile (docs/architecture.md §9).

    Layers, top down:
      * MTU-aware chunking/reassembly of large tensors through the
        ``comm/wire.py`` frame format — float batches ride the fused int8
        block-quantized encode (the compressed wire), opaque byte payloads
        are chunked raw, small control messages pass through whole;
      * go-back-N retransmission: every frame batch goes through one
        ``ReliableChannel.request_window`` call, so delivery is confirmed
        (``send`` returns only once the peer acked the window) and loss is
        repaired by retransmit instead of surfacing to the application;
      * keepalives: ``ping()`` probes the peer fail-fast, ``alive()`` tracks
        last-heard age, so a region notices a partition without waiting for
        a full send to stall out.

    Unilateral by design: the peer is a dedicated WAN gateway endpoint
    (``repro.serving.gateway.WanGateway``) that always speaks this frame
    format, so a region can adopt or drop the WAN stack without negotiating
    with anyone — the same shape as the serving plane's ClientShard option.
    """

    upper_type = WireType.of("bytes")
    lower_type = UNIT
    multilateral = False

    def __init__(self, ep, peer: str, *, mtu_bytes: int = 4096,
                 window: int = 8, timeout_s: float = 0.03, retries: int = 8,
                 keepalive_s: float = 0.25, block: int = 256,
                 use_kernel: bool = False, max_partial: int = 64,
                 label: str = "WanLink"):
        self.ep = ep
        self.peer = peer
        self.mtu_bytes = mtu_bytes
        self.window = window
        self.timeout_s = timeout_s
        self.retries = retries
        self.keepalive_s = keepalive_s
        self.block = block
        self.use_kernel = use_kernel
        self.max_partial = max_partial
        self._label = label

    @property
    def name(self) -> str:
        return self._label

    def capabilities(self) -> CapabilitySet:
        # compose, not exact: the gateway side always speaks the WAN frame
        # format, so adopting it is a one-sided decision per region
        return CapabilitySet.compose("link:wan-gbn", f"link:q8b{self.block}")

    def cost_model(self) -> CostModel:
        return CostModel(op_latency_s=2e-3,
                         dcn_bytes_per_byte=compress.int8_wire_ratio(self.block),
                         switch_blip_s=2e-3)

    def connect_wrap(self, inner: Optional[Datapath]) -> Datapath:
        assert inner is None, "transport chunnels bootstrap from the unit type"
        return _WanLinkDP(self)


def _is_float_tensor(m) -> bool:
    dt = getattr(m, "dtype", None)
    if dt is None:
        return False
    try:
        return np.issubdtype(np.dtype(dt), np.floating)
    except TypeError:
        return False


class _WanLinkDP(Datapath):
    """Live WAN link: one ``request_window`` per batch on the send side, a
    ``serve_one`` pump + bounded ``Reassembler`` on the receive side."""

    def __init__(self, ch: WanLinkChunnel):
        from repro.comm.wire import Reassembler
        from repro.core.fabric import ReliableChannel

        self.ch = ch
        self._chan = ReliableChannel(ch.ep, ch.peer, timeout=ch.timeout_s,
                                     retries=ch.retries, window=ch.window)
        self._reasm = Reassembler(max_partial=ch.max_partial)
        self._ready: deque = deque()
        self._last_heard = time.monotonic()
        self.msgs_sent = 0
        self.frames_sent = 0
        self.failed_sends = 0
        self.pings_ok = 0
        self.keepalive_failures = 0

    # -- send: classify, encode, one reliable window per batch ----------------
    def send(self, msgs):
        from repro.comm.wire import chunk_payload, encode_batch

        msgs = list(msgs)
        if not msgs:
            return
        # ONE batch-level span (the span-in-hot-loop rule forbids per-frame
        # spans here); chunk headers inherit its ctx inside chunk_payload,
        # and the rc.window span underneath tags each retransmit retry=n.
        sp = (TRACER.span("wan.send", attrs={"peer": self.ch.peer,
                                             "n": len(msgs),
                                             "chunnel": self.ch.name})
              if TRACER.enabled else NOOP_SPAN)
        with sp:
            frames: list = []
            tensors: list = []

            def flush_tensors():
                if tensors:
                    frames.extend(encode_batch(
                        tensors, block=self.ch.block,
                        use_kernel=self.ch.use_kernel,
                        chunk_bytes=self.ch.mtu_bytes))
                    tensors.clear()

            for m in msgs:
                if _is_float_tensor(m):
                    tensors.append(m)  # contiguous runs share one device call
                elif isinstance(m, (bytes, bytearray)):
                    flush_tensors()
                    frames.extend(chunk_payload(bytes(m), {"kind": "raw"},
                                                chunk_bytes=self.ch.mtu_bytes))
                else:
                    flush_tensors()
                    frames.append({"_obj": m})
            flush_tensors()
            self.msgs_sent += len(msgs)
            self.frames_sent += len(frames)
            sp.set(frames=len(frames))
            try:
                self._chan.request_window(frames)
            except TimeoutError:
                self.failed_sends += 1
                # the batch is NOT delivered: close the span as a drop
                sp.set(status="dropped", drop_reason="window_stalled")
                raise
            self._last_heard = time.monotonic()

    # -- receive: pump the reliable server side into the ready queue ----------
    def recv(self, buf, timeout=None):
        n_out = self._drain(buf, 0)
        deadline = None if timeout is None else time.monotonic() + timeout
        while n_out < len(buf):
            if n_out:
                t: Optional[float] = 0.0  # drain-only once delivering
            elif deadline is None:
                t = None
            else:
                t = deadline - time.monotonic()
                if t <= 0:
                    break
            if not self._chan.serve_one(self._ingest_frame, timeout=t):
                if n_out or t == 0.0:
                    break
                continue  # spurious wakeup (stray frame): keep waiting
            n_out = self._drain(buf, n_out)
        return n_out

    def _ingest_frame(self, src, body):
        from repro.comm.wire import decode_blob

        self._last_heard = time.monotonic()
        if isinstance(body, dict):
            if "_wire" in body:
                done = self._reasm.ingest(body)
                if done is not None:
                    payload, hdr = done
                    if TRACER.enabled:
                        TRACER.event("wire.reassembled",
                                     attrs={"bytes": len(payload),
                                            "kind": hdr.get("kind", "tensor")},
                                     ctx=hdr.get("tc"))
                    if hdr.get("kind") == "raw":
                        self._ready.append(payload)
                    else:
                        self._ready.extend(decode_blob(
                            payload, hdr, use_kernel=self.ch.use_kernel))
                return {"ok": True}
            if "_ka" in body:
                return {"pong": True}
            if "_obj" in body:
                self._ready.append(body["_obj"])
                return {"ok": True}
        self._ready.append(body)
        return {"ok": True}

    def _drain(self, buf, n_out: int) -> int:
        while n_out < len(buf) and self._ready:
            buf[n_out] = self._ready.popleft()
            n_out += 1
        return n_out

    # -- keepalives ------------------------------------------------------------
    def ping(self, retries: int = 3) -> bool:
        """Fail-fast liveness probe; updates last-heard on success."""
        try:
            self._chan.request({"_ka": True}, retries=retries)
        except TimeoutError:
            self.keepalive_failures += 1
            return False
        self.pings_ok += 1
        self._last_heard = time.monotonic()
        return True

    def alive(self, now: Optional[float] = None, grace: float = 3.0) -> bool:
        """Heard from the peer within ``grace`` keepalive periods?"""
        now = time.monotonic() if now is None else now
        return (now - self._last_heard) <= grace * self.ch.keepalive_s

    def keepalive_due(self, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        return (now - self._last_heard) >= self.ch.keepalive_s

    # -- observability ----------------------------------------------------------
    @property
    def retransmits(self) -> int:
        return self._chan.retransmits

    def stats(self) -> dict:
        """Link-health counters a region controller can fold into its
        telemetry snapshot (``link.*`` keys in ``wan_region_adaptive``)."""
        return {
            "msgs_sent": self.msgs_sent,
            "frames_sent": self.frames_sent,
            "failed_sends": self.failed_sends,
            "retransmits": self._chan.retransmits,
            "retransmit_ratio":
                self._chan.retransmits / max(1, self.frames_sent),
            "keepalive_failures": self.keepalive_failures,
            "partial_blobs": self._reasm.partial_count(),
            "evicted_partials": self._reasm.evicted,
        }


@register_policy("wan_region_adaptive")
def wan_region_adaptive_policy(ctx: PolicyContext) -> List[Rule]:
    """Per-region link-health policy (ROADMAP direction 5): a lossy region
    moves its Select to the WAN compressed+reliable option; a region whose
    link is clean (and whose WAN datapath isn't retransmitting) recovers to
    the fast path. Reads two scenario-fed snapshot keys:

      link.timeout_ratio     fraction of recent probes that timed out
                             (1.0 during a hard partition)
      link.retransmit_ratio  WAN-link retransmits per frame sent — nonzero
                             while the link still drops frames, so recovery
                             only arms on genuinely clean links
    """
    p = ctx.params
    breach = p.get("breach_timeout_ratio", 0.05)
    recover = p.get("recover_timeout_ratio", 0.01)
    rtx_ok = p.get("recover_retransmit_ratio", 0.02)
    hold = p.get("hold", 2)
    wan = ctx.candidate_named(*p.get("wan_names", ("WanLink",))).target
    fast = ctx.candidate_named(
        *p.get("fast_names", ("FastWire", "FabricTransport"))).target
    return [
        Rule("lossy-wan->compressed-reliable",
             above("link.timeout_ratio", breach), wan,
             hold=hold, priority=1),
        Rule("clean-link->fast-path",
             all_of(below("link.timeout_ratio", recover),
                    below("link.retransmit_ratio", rtx_ok)),
             fast, hold=hold, priority=0),
    ]
