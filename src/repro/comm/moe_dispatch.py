"""MoE dispatch chunnels: the negotiation-facing wrappers for the expert
dispatch Select implemented in repro/models/moe.py.

  grouped    capacity gather/scatter, schedule left to XLA (paper-faithful)
  alltoall   explicit EP all-to-all over 'model' (2 a2a + AG per MoE layer)
  allgather  local-experts-for-all-tokens + psum combine (1 AR per MoE layer)

All are multilateral (SPMD) with exact capability labels; dense is the oracle.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.capability import CapabilitySet
from repro.comm.chunnels import StepChunnel


@dataclass
class MoEDispatch(StepChunnel):
    impl: str = "grouped"  # dense | grouped | alltoall | allgather
    axis: str = "model"

    def __post_init__(self):
        self.manual_axes = (self.axis,) if self.impl in ("alltoall", "allgather") else ()

    @property
    def name(self):
        return f"MoEDispatch[{self.impl}]"

    def capabilities(self):
        return CapabilitySet.exact(f"moe:{self.impl}@{self.axis}")

    def apply(self, tree, state, ctx):
        return tree, state  # resolved via ModelConfig.moe.dispatch at trace time


def configure(cfg, impl: str):
    """Return a config with the negotiated dispatch impl."""
    import dataclasses

    return cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch=impl))
