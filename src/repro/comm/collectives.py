"""Collective implementations over a mesh axis (the TPU 'transports').

These are the alternative implementations behind the gradient-transport Select
(DESIGN.md §2): all compute the same all-reduce, with different schedules and
wire formats, hence different collective-roofline terms:

  psum_tree          XLA-native all-reduce (one fused AR)
  ring_tree          explicit bidirectional-ring RS+AG via ppermute
                     (2(n-1) steps; overlap-friendly schedule on real links)
  hierarchical_tree  reduce-scatter over the fast (intra-pod ICI) axis, then
                     all-reduce over the slow (DCN) axis on 1/|fast| shards,
                     then all-gather — per-chip DCN bytes divided by |fast|
  compressed_tree    int8 block-quantized all-gather over the slow axis
                     (4x DCN bytes vs fp32) with error feedback upstream

All functions run INSIDE a shard_map manual over the named axes and are
numerically interchangeable (tested against psum_tree).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro import compat

from repro.comm import compress


def dcn_bytes_factor(schedule: str, *, n_fast: int = 1, sync_every: int = 1,
                     wire_ratio: float = 1.0) -> float:
    """Per-payload-byte DCN traffic of each schedule, relative to one fused
    f32 all-reduce — the ``dcn_bytes_per_byte`` cost-model term behind the
    gradient-transport Select:

      psum/ring/xla   1.0   (full f32 gradients cross the slow tier)
      hierarchical    1/n_fast  (each chip moves only its RS shard over DCN)
      compressed      wire_ratio (see ``compress.int8_wire_ratio``)
      hier_compressed wire_ratio/n_fast
      localsgd        1/sync_every (full sync every H steps, amortized)
    """
    if schedule in ("hierarchical",):
        return 1.0 / max(n_fast, 1)
    if schedule in ("compressed", "compressed_int8", "cag"):
        return wire_ratio
    if schedule in ("hier_compressed", "hiercag"):
        return wire_ratio / max(n_fast, 1)
    if schedule == "localsgd":
        return 1.0 / max(sync_every, 1)
    return 1.0  # xla / psum / ring


def _flatten(tree) -> Tuple[jnp.ndarray, list, list]:
    leaves = jax.tree.leaves(tree)
    shapes = [l.shape for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves]) if leaves else jnp.zeros((0,))
    return flat, shapes, jax.tree.structure(tree)


def _unflatten(flat: jnp.ndarray, shapes, treedef, like_tree):
    out, off = [], 0
    dtypes = [l.dtype for l in jax.tree.leaves(like_tree)]
    for shp, dt in zip(shapes, dtypes):
        n = 1
        for s in shp:
            n *= s
        out.append(flat[off : off + n].reshape(shp).astype(dt))
        off += n
    return jax.tree.unflatten(treedef, out)


def psum_tree(tree, axis: str):
    return jax.tree.map(lambda g: jax.lax.psum(g, axis), tree)


def pmean_tree(tree, axis: str):
    return jax.tree.map(lambda g: jax.lax.pmean(g, axis), tree)


def ring_allreduce(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Ring all-reduce of a flat vector via 2(n-1) collective-permutes."""
    n = compat.named_axis_size(axis)
    if n == 1:
        return x
    rank = jax.lax.axis_index(axis)
    perm = [(j, (j + 1) % n) for j in range(n)]
    size = x.shape[0]
    pad = (-size) % n
    xp = jnp.pad(x, (0, pad))
    chunks = xp.reshape(n, -1)

    def rs_step(i, c):
        send = c[(rank - i + 1) % n]
        recv = jax.lax.ppermute(send, axis, perm)
        return c.at[(rank - i) % n].add(recv)

    chunks = jax.lax.fori_loop(1, n, rs_step, chunks, unroll=True)
    my = (rank + 1) % n
    cur = chunks[my]
    out = jnp.zeros_like(chunks).at[my].set(cur)

    def ag_step(i, st):
        acc, cur = st
        nxt = jax.lax.ppermute(cur, axis, perm)
        return acc.at[(rank - i + 1) % n].set(nxt), nxt

    out, _ = jax.lax.fori_loop(1, n, ag_step, (out, cur), unroll=True)
    return out.reshape(-1)[:size]


def ring_tree(tree, axis: str):
    flat, shapes, treedef = _flatten(tree)
    return _unflatten(ring_allreduce(flat, axis), shapes, treedef, tree)


def hierarchical_tree(tree, fast_axis: str, slow_axis: str):
    """RS(fast) -> AR(slow) on 1/|fast| shards -> AG(fast).

    Balances DCN traffic: every chip moves only its 1/|fast| gradient shard
    across the slow tier instead of the full tree.
    """
    flat, shapes, treedef = _flatten(tree)
    n_fast = compat.named_axis_size(fast_axis)
    pad = (-flat.shape[0]) % n_fast
    xp = jnp.pad(flat, (0, pad))
    shard = jax.lax.psum_scatter(xp.reshape(n_fast, -1), fast_axis, scatter_dimension=0,
                                 tiled=False)
    shard = jax.lax.psum(shard, slow_axis)
    full = jax.lax.all_gather(shard, fast_axis, axis=0, tiled=False)
    return _unflatten(full.reshape(-1)[: flat.shape[0]], shapes, treedef, tree)


def compressed_allgather_sum(x: jnp.ndarray, axis: str, *, block: int = 256,
                             use_kernel: bool = False) -> jnp.ndarray:
    """All-reduce with an int8 block-quantized wire format over ``axis``.

    Each rank quantizes its vector, all-gathers the (int8, fp32-scale) pair
    (1/4 the fp32 bytes + ~1/block scale overhead) and dequant-sums locally.
    """
    n = compat.named_axis_size(axis)
    if n == 1:
        return x
    q, scales = compress.quantize_int8(x, block=block, use_kernel=use_kernel)
    q_all = jax.lax.all_gather(q, axis, axis=0, tiled=False)  # (n, ...)
    s_all = jax.lax.all_gather(scales, axis, axis=0, tiled=False)
    deq = jax.vmap(lambda qq, ss: compress.dequantize_int8(qq, ss, x.shape, block=block))(
        q_all, s_all
    )
    return jnp.sum(deq, axis=0)


def compressed_tree(tree, slow_axis: str, *, block: int = 256, use_kernel: bool = False):
    flat, shapes, treedef = _flatten(tree)
    out = compressed_allgather_sum(flat, slow_axis, block=block, use_kernel=use_kernel)
    return _unflatten(out, shapes, treedef, tree)


def hierarchical_compressed_tree(tree, fast_axis: str, slow_axis: str, *, block: int = 256,
                                 use_kernel: bool = False):
    """Beyond-paper combination: RS(fast) -> compressed AR(slow) -> AG(fast)."""
    flat, shapes, treedef = _flatten(tree)
    n_fast = compat.named_axis_size(fast_axis)
    pad = (-flat.shape[0]) % n_fast
    xp = jnp.pad(flat, (0, pad))
    shard = jax.lax.psum_scatter(xp.reshape(n_fast, -1), fast_axis, scatter_dimension=0,
                                 tiled=False)
    shard = compressed_allgather_sum(shard, slow_axis, block=block, use_kernel=use_kernel)
    full = jax.lax.all_gather(shard, fast_axis, axis=0, tiled=False)
    return _unflatten(full.reshape(-1)[: flat.shape[0]], shapes, treedef, tree)
