"""Fused compressed wire path: the host-plane chunnel that makes
``use_kernel=True`` real (docs/architecture.md §8, ROADMAP direction 1).

The gradient-compression step chunnel models its int8 wire ratio; this module
actually SHIPS the compressed bytes over the host fabric. The whole batch of
float messages is flattened host-side, then one jitted device program fuses
quantize → pack-to-bytes (int8 payload + bitcast fp32 scales into a single
uint8 vector); the receive side runs the inverse unpack → dequantize in one
program and splits back into per-message arrays. ``use_kernel=True`` routes
the quantize/dequantize through the Pallas TPU kernels in
``repro.kernels.quantize`` (interpret mode off-TPU); ``use_kernel=False`` is
the pure-jnp oracle — tier-1 tests assert the two produce identical wire
bytes in interpret mode.
"""
from __future__ import annotations

import functools
import itertools
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import numpy as np
from jax import numpy as jnp

from repro.comm.compress import int8_wire_ratio
from repro.core.capability import CapabilitySet
from repro.core.chunnel import Chunnel, Datapath, WireType
from repro.core.cost import CostModel
from repro.kernels.quantize.ops import INTERPRET
from repro.kernels.quantize.quantize import dequantize_blocks, quantize_blocks
from repro.obs.trace import TRACER

TENSOR = WireType.of("tensor", dtype="f32")
BYTES = WireType.of("bytes")

# blob ids only disambiguate concurrent reassembly on one receiving datapath;
# process-global uniqueness is plenty
_BLOB_IDS = itertools.count(1)
_BLOB_LOCK = threading.Lock()


def _next_blob_id() -> int:
    with _BLOB_LOCK:
        return next(_BLOB_IDS)


@functools.partial(jax.jit, static_argnames=("block", "use_kernel"))
def _fused_encode(x2d: jnp.ndarray, *, block: int, use_kernel: bool) -> jnp.ndarray:
    """(n_blocks, block) f32 -> one uint8 vector: int8 payload then bitcast
    fp32 scales. One device program for the whole batch."""
    if use_kernel:
        q, s = quantize_blocks(x2d, block=block, interpret=INTERPRET)
    else:
        amax = jnp.max(jnp.abs(x2d), axis=1)
        s = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
        q = jnp.clip(jnp.round(x2d / s[:, None]), -127, 127).astype(jnp.int8)
    qb = jax.lax.bitcast_convert_type(q, jnp.uint8).reshape(-1)
    sb = jax.lax.bitcast_convert_type(s, jnp.uint8).reshape(-1)
    return jnp.concatenate([qb, sb])


@functools.partial(jax.jit, static_argnames=("n_blocks", "block", "use_kernel"))
def _fused_decode(packed: jnp.ndarray, *, n_blocks: int, block: int,
                  use_kernel: bool) -> jnp.ndarray:
    """Inverse of ``_fused_encode``: uint8 vector -> flat f32 of length
    n_blocks * block, again one device program."""
    qb = packed[: n_blocks * block].reshape(n_blocks, block)
    q = jax.lax.bitcast_convert_type(qb, jnp.int8)
    sb = packed[n_blocks * block:].reshape(n_blocks, 4)
    s = jax.lax.bitcast_convert_type(sb, jnp.float32)
    if use_kernel:
        out = dequantize_blocks(q, s, block=block, interpret=INTERPRET)
    else:
        out = q.astype(jnp.float32) * s[:, None]
    return out.reshape(-1)


def chunk_payload(payload: bytes, hdr: dict, *,
                  chunk_bytes: int = 1 << 16) -> List[dict]:
    """Split one blob into MTU-sized ``{"_wire": (id, k, n), "hdr", "data"}``
    fabric frames (header rides chunk 0 only). The generic framing layer under
    both the compressed wire path and the WAN link chunnel.

    When tracing is enabled, the sender's current trace ctx rides the header
    (``hdr["tc"]``) so the receive side can stitch reassembly — and eviction
    under loss — back to the span that sent the blob."""
    if TRACER.enabled:
        tc = TRACER.ctx()
        if tc is not None:
            hdr = dict(hdr)  # never mutate the caller's header
            hdr["tc"] = tc
    blob_id = _next_blob_id()
    n_chunks = max(1, -(-len(payload) // chunk_bytes))
    return [{"_wire": (blob_id, k, n_chunks),
             "hdr": hdr if k == 0 else None,
             "data": payload[k * chunk_bytes:(k + 1) * chunk_bytes]}
            for k in range(n_chunks)]


def encode_batch(msgs: List[Any], *, block: int = 256, use_kernel: bool = True,
                 chunk_bytes: int = 1 << 16) -> List[dict]:
    """Batch of float arrays -> wire frames. One host concat, one fused
    device call, then chunking into ``chunk_bytes`` fabric frames."""
    arrs = [np.asarray(m, dtype=np.float32) for m in msgs]
    shapes = [a.shape for a in arrs]
    total = int(sum(a.size for a in arrs))
    if total:
        flat = np.concatenate([a.reshape(-1) for a in arrs])
        pad = (-total) % block
        if pad:
            flat = np.pad(flat, (0, pad))
        x2d = flat.reshape(-1, block)
        packed = _fused_encode(jnp.asarray(x2d), block=block, use_kernel=use_kernel)
        payload = np.asarray(packed, dtype=np.uint8).tobytes()
        n_blocks = x2d.shape[0]
    else:
        payload = b""
        n_blocks = 0
    hdr = {"shapes": [tuple(s) for s in shapes], "block": block,
           "n_blocks": n_blocks}
    return chunk_payload(payload, hdr, chunk_bytes=chunk_bytes)


def decode_blob(payload: bytes, hdr: dict, *, use_kernel: bool = True) -> List[np.ndarray]:
    """Reassembled payload + header -> the original batch (dequantized)."""
    shapes = hdr["shapes"]
    n_blocks, block = hdr["n_blocks"], hdr["block"]
    if n_blocks:
        packed = jnp.asarray(np.frombuffer(payload, dtype=np.uint8))
        flat = np.asarray(_fused_decode(packed, n_blocks=n_blocks, block=block,
                                        use_kernel=use_kernel))
    else:
        flat = np.zeros((0,), dtype=np.float32)
    out: List[np.ndarray] = []
    off = 0
    for shp in shapes:
        size = int(np.prod(shp)) if shp else 1
        out.append(flat[off:off + size].reshape(shp))
        off += size
    return out


class Reassembler:
    """Bounded reassembly of ``chunk_payload`` frames into whole blobs.

    ``ingest`` returns ``(payload, hdr)`` when a blob completes, else None.
    At most ``max_partial`` blobs are held; under sustained frame loss (or a
    partition mid-blob) the oldest partial is evicted, so reassembly state
    stays bounded no matter how hostile the link. Single-consumer, like the
    datapaths that own it."""

    def __init__(self, max_partial: int = 64):
        self.max_partial = max_partial
        self._partial: Dict[int, dict] = {}
        self._order: deque = deque()
        self.evicted = 0  # partial blobs dropped at the bound

    def ingest(self, frame: Any) -> Optional[tuple]:
        if not (isinstance(frame, dict) and "_wire" in frame):
            return None
        blob_id, k, n_chunks = frame["_wire"]
        st = self._partial.get(blob_id)
        if st is None:
            st = {"hdr": None, "chunks": {}, "n": n_chunks}
            self._partial[blob_id] = st
            self._order.append(blob_id)
            while len(self._order) > self.max_partial:
                victim = self._partial.pop(self._order.popleft(), None)
                if victim is not None:
                    self.evicted += 1
                    if TRACER.enabled:
                        # close the sender's span story: the blob died here
                        TRACER.event(
                            "wire.evicted",
                            attrs={"drop_reason": "reassembly_overflow",
                                   "chunks_held": len(victim["chunks"])},
                            ctx=(victim.get("hdr") or {}).get("tc"))
        if frame.get("hdr") is not None:
            st["hdr"] = frame["hdr"]
        st["chunks"][k] = frame["data"]
        if st["hdr"] is not None and len(st["chunks"]) == st["n"]:
            self._partial.pop(blob_id, None)
            payload = b"".join(st["chunks"][i] for i in range(st["n"]))
            return payload, st["hdr"]
        return None

    def partial_count(self) -> int:
        return len(self._partial)


@dataclass
class CompressChunnel(Chunnel):
    """Host-plane int8 compressed wire format (exact-match capability: every
    peer must speak it). ``use_kernel=True`` is the Pallas path (interpret
    mode off-TPU); ``False`` the jnp oracle — same bytes either way."""

    block: int = 256
    use_kernel: bool = True
    chunk_bytes: int = 1 << 16

    upper_type = TENSOR
    lower_type = BYTES
    multilateral = True

    @property
    def name(self) -> str:
        return f"CompressWire[b{self.block}]"

    def capabilities(self) -> CapabilitySet:
        return CapabilitySet.exact(f"wire:int8-blockq{self.block}")

    def cost_model(self) -> CostModel:
        return CostModel(op_latency_s=5e-4,
                         dcn_bytes_per_byte=int8_wire_ratio(self.block),
                         switch_blip_s=1e-3)

    def connect_wrap(self, inner: Optional[Datapath]) -> Datapath:
        return _CompressDP(self, inner)


class _CompressDP(Datapath):
    """Fused-wire datapath: encode the whole batch in one device call, chunk,
    and reassemble/decode on the receive side."""

    MAX_PARTIAL = 64  # bound reassembly state under frame loss

    def __init__(self, ch: CompressChunnel, inner: Optional[Datapath]):
        self.ch = ch
        self.inner = inner
        self._reasm = Reassembler(max_partial=self.MAX_PARTIAL)
        self._ready: deque = deque()

    def send(self, msgs):
        msgs = list(msgs)
        if not msgs:
            return
        frames = encode_batch(msgs, block=self.ch.block,
                              use_kernel=self.ch.use_kernel,
                              chunk_bytes=self.ch.chunk_bytes)
        if self.inner is not None:
            self.inner.send(frames)

    def recv(self, buf, timeout=None):
        n_out = self._drain(buf, 0)
        if self.inner is None:
            return n_out
        tmp: List[Any] = [None] * max(len(buf), 8)
        deadline = None if timeout is None else time.monotonic() + timeout
        while n_out < len(buf):
            if n_out:
                t: Optional[float] = 0.0  # drain-only once delivering
            elif deadline is None:
                t = None
            else:
                t = deadline - time.monotonic()
                if t <= 0:
                    break  # partial blobs are kept for the next call
            got = self.inner.recv(tmp, t)
            if not got:
                break
            for k in range(got):  # reassemble chunked blobs
                self._ingest(tmp[k])
            n_out = self._drain(buf, n_out)
        return n_out

    def _ingest(self, frame) -> None:
        done = self._reasm.ingest(frame)
        if done is not None:
            payload, hdr = done
            if TRACER.enabled:
                # parented to the SENDER's span via the header trace ctx:
                # this is where a trace crosses chunking + reassembly
                TRACER.event("wire.reassembled",
                             attrs={"bytes": len(payload),
                                    "msgs": len(hdr.get("shapes") or ())},
                             ctx=hdr.get("tc"))
            self._ready.extend(decode_blob(payload, hdr,
                                           use_kernel=self.ch.use_kernel))

    def _drain(self, buf, n_out: int) -> int:
        while n_out < len(buf) and self._ready:
            buf[n_out] = self._ready.popleft()
            n_out += 1
        return n_out
