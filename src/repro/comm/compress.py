"""Gradient wire formats: int8 block quantization (+ error feedback).

This is the 'serialization chunnel' analogue (exact-match capability — every
peer must speak the same wire format). The jnp implementation here is the
oracle; the Pallas TPU kernel lives in kernels/quantize and is selected with
``use_kernel=True`` on real hardware (validated in interpret mode in tests).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def int8_wire_ratio(block: int = 256) -> float:
    """Wire bytes per f32 payload byte of the int8 block format: one int8 per
    4-byte float plus one f32 scale per block — the ``dcn_bytes_per_byte``
    cost-model term of every chunnel speaking this format."""
    return (1.0 + 4.0 / block) / 4.0


def _pad_to_block(x: jnp.ndarray, block: int) -> jnp.ndarray:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    return jnp.pad(flat, (0, pad))


def quantize_int8(x: jnp.ndarray, *, block: int = 256,
                  use_kernel: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (any shape) -> (q int8 (nblocks, block), scales fp32 (nblocks,))."""
    if use_kernel:
        from repro.kernels.quantize import ops as qops

        return qops.quantize_int8(x, block=block)
    flat = _pad_to_block(x, block).reshape(-1, block)
    amax = jnp.max(jnp.abs(flat), axis=1)
    scales = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(flat / scales[:, None]), -127, 127).astype(jnp.int8)
    return q, scales


def dequantize_int8(q: jnp.ndarray, scales: jnp.ndarray, shape, *, block: int = 256,
                    use_kernel: bool = False) -> jnp.ndarray:
    if use_kernel:
        from repro.kernels.quantize import ops as qops

        return qops.dequantize_int8(q, scales, shape, block=block)
    n = 1
    for s in shape:
        n *= s
    flat = (q.astype(jnp.float32) * scales[:, None]).reshape(-1)[:n]
    return flat.reshape(shape)


def quantize_error(x: jnp.ndarray, *, block: int = 256) -> jnp.ndarray:
    """Residual x - dq(q(x)) for error feedback."""
    q, s = quantize_int8(x, block=block)
    return x - dequantize_int8(q, s, x.shape, block=block)
