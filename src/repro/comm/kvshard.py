"""KV-cache partitioning chunnels for decode (a Bertha routing Select).

  heads     — KV heads sharded over 'model' (only when kv_heads % |model| == 0:
              phi-3 (32), seamless (16)); plain local attention per shard.
  sequence  — cache SEQUENCE sharded over 'model' (granite kv=1, hymba kv=5,
              qwen/mistral/dbrx kv∤16): flash-decoding — each rank computes
              partial (m, l, o) over its sequence shard, combined with a
              logsumexp-weighted psum across 'model'.

Decode is memory-bound; sequence sharding spreads the dominant HBM stream
(the cache read) across all chips regardless of kv-head count.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat

from repro.core.capability import CapabilitySet
from repro.comm.chunnels import StepChunnel

NEG_INF = -1e30


def _expand_kv(x, group):
    return x if group == 1 else jnp.repeat(x, group, axis=2)


def flash_decode_local(q, k_loc, v_loc, start, kv_len, window=None):
    """Partial attention over a local cache shard.

    q: (B,1,H,hd); k_loc/v_loc: (B,S_loc,KH,hd); start: global pos of shard[0].
    Returns (o (B,H,hd) fp32, l (B,H) fp32, m (B,H) fp32).
    """
    B, _, H, hd = q.shape
    KH = k_loc.shape[2]
    k = _expand_kv(k_loc, H // KH)
    v = _expand_kv(v_loc, H // KH)
    scale = hd**-0.5
    s = jnp.einsum("bqhd,bkhd->bhk", q.astype(jnp.bfloat16),
                   k.astype(jnp.bfloat16)).astype(jnp.float32) * scale
    kpos = start + jnp.arange(k.shape[1])
    valid = kpos[None, :] < jnp.asarray(kv_len).reshape(-1, 1)
    if window is not None:
        valid &= kpos[None, :] >= jnp.asarray(kv_len).reshape(-1, 1) - window
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, :], p, 0.0)  # kill exp(NEG_INF - NEG_INF)=1 rows
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhk,bkhd->bhd", p.astype(jnp.bfloat16),
                   v.astype(jnp.bfloat16)).astype(jnp.float32)
    return o, l, m


def make_seq_sharded_decode(mesh, axis: str = "model"):
    """Returns attn_fn(q, k_cache, v_cache, kv_len, window) with the cache
    sequence dim manual over ``axis`` and flash-decode combine."""

    def attn_fn(q, k_cache, v_cache, kv_len, window=None):
        def inner(q_, kc, vc, n_):
            rank = jax.lax.axis_index(axis)
            S_loc = kc.shape[1]
            o, l, m = flash_decode_local(q_, kc, vc, rank * S_loc, n_, window)
            m_g = jax.lax.pmax(m, axis)
            corr = jnp.exp(m - m_g)
            l_g = jax.lax.psum(l * corr, axis)
            o_g = jax.lax.psum(o * corr[..., None], axis)
            out = o_g / jnp.maximum(l_g, 1e-20)[..., None]
            return out[:, None].astype(q_.dtype)  # (B,1,H,hd)

        f = compat.shard_map(
            inner,
            mesh=mesh,
            in_specs=(P(), P(None, axis, None, None), P(None, axis, None, None), P()),
            out_specs=P(),
            check_vma=False,
            axis_names={axis},
        )
        return f(q, k_cache, v_cache, jnp.asarray(kv_len))

    return attn_fn


# ---------------------------------------------------------------------------
# Chunnel wrappers (negotiated; compositional capability — routing-style)
# ---------------------------------------------------------------------------


@dataclass
class KVHeadSharded(StepChunnel):
    axis: str = "model"

    @property
    def name(self):
        return "KVHeadSharded"

    def capabilities(self):
        return CapabilitySet.compose(f"kvshard:heads@{self.axis}")

    def apply(self, tree, state, ctx):
        return tree, state  # layout-only: sharding specs select head partitioning


@dataclass
class KVSeqSharded(StepChunnel):
    axis: str = "model"

    @property
    def name(self):
        return "KVSeqSharded"

    def capabilities(self):
        return CapabilitySet.compose(f"kvshard:sequence@{self.axis}")

    def attn_fn(self, mesh):
        return make_seq_sharded_decode(mesh, self.axis)

    def apply(self, tree, state, ctx):
        return tree, state


def pick_kv_chunnel(cfg, mesh, sharding_cfg) -> StepChunnel:
    from repro.models.sharding import kv_partition_mode

    mode = kv_partition_mode(cfg, mesh, sharding_cfg)
    return KVHeadSharded() if mode == "heads" else KVSeqSharded()
