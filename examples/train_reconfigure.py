"""End-to-end driver: train a ~small LM for a few hundred steps with
checkpoint/restart and a live transport reconfiguration mid-run.

    PYTHONPATH=src python examples/train_reconfigure.py [--steps 200]

Shows the paper's pitch on the training plane:
  * negotiation picks the transport all hosts support,
  * a straggler (injected slowdown) triggers a negotiated transition to the
    DCN-lighter compressed transport WITHOUT losing step state,
  * a kill + restore resumes from the atomic checkpoint (same loss curve).
"""
import argparse
import tempfile

import jax
import numpy as np
from repro import compat

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.data.synthetic import batches_for
from repro.launch.mesh import make_test_mesh
from repro.train.trainer import HostSpec, ReconfigurableTrainer, StragglerPolicy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    cfg = get_smoke_config("llama3.2-1b")
    shape = ShapeConfig("e2e", 128, 8, "train")
    mesh = make_test_mesh((2, 4), ("pod", "model"))  # tiny 'pod' axis on CPU
    compat.set_mesh(mesh)
    ckpt_dir = tempfile.mkdtemp(prefix="berthax-ckpt-")

    trainer = ReconfigurableTrainer(
        cfg, shape, mesh,
        tcfg=TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=args.steps),
        transport="psum",
        ckpt_dir=ckpt_dir,
        hosts=[HostSpec(0, ["psum", "compressed_int8"]),
               HostSpec(1, ["psum", "compressed_int8"])],
    )
    print(f"negotiated transport: {trainer.transport_name}")
    state = trainer.init_state(jax.random.PRNGKey(0))
    gen = batches_for(cfg, shape)

    half = args.steps // 2
    # phase 1: normal training; a straggler appears after 1/4 of the steps
    state, hist1 = trainer.run(
        state, gen, half, ckpt_every=20,
        straggler=StragglerPolicy(window=8, slow_factor=1.4,
                                  fallback="compressed_int8"),
        inject_slow=lambda i: 0.05 if i > half // 2 else 0.0,
    )
    print(f"phase1 loss {hist1[0]['loss']:.3f} -> {hist1[-1]['loss']:.3f}; "
          f"reconfigurations: {trainer.reconfig_log}")

    # simulate a crash: restore from the last atomic checkpoint
    trainer.save(state)
    restored, at = trainer.restore()
    print(f"restored at step {at}")
    state = restored

    # phase 2: continue on the (possibly reconfigured) stack
    state, hist2 = trainer.run(state, gen, args.steps - half)
    print(f"phase2 loss {hist2[0]['loss']:.3f} -> {hist2[-1]['loss']:.3f} "
          f"(transport now: {trainer.transport_name})")
    assert np.isfinite(hist2[-1]["loss"])
    assert hist2[-1]["loss"] < hist1[0]["loss"], "loss should improve across restart"
    print("train_reconfigure OK")


if __name__ == "__main__":
    main()
