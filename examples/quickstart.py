"""Quickstart: build a chunnel stack, negotiate, train a small LM, reconfigure.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro import compat
from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.core import Fabric, FnChunnel, HostAgent, Select, make_stack
from repro.core.capability import CapabilitySet
from repro.data.synthetic import batches_for
from repro.launch.mesh import make_test_mesh
from repro.train.trainer import HostSpec, ReconfigurableTrainer

# ---------------------------------------------------------------------------
# 1. The paper's abstractions: stacks, selects, negotiation
# ---------------------------------------------------------------------------
fabric = Fabric()
server, client = HostAgent(fabric, "srv"), HostAgent(fabric, "cli")

kafka = FnChunnel(fn_name="Kafka", caps=CapabilitySet.exact("pubsub:kafka"))
sqs = FnChunnel(fn_name="SQS", caps=CapabilitySet.exact("pubsub:sqs"))
server.listen(make_stack(Select(kafka, sqs)))  # server prefers kafka
conn = client.connect("srv", make_stack(sqs))  # client only speaks sqs
print(f"negotiated stack: {conn.stack} (nonce={conn.nonce})")
server.close(); client.close()

# ---------------------------------------------------------------------------
# 2. The same machinery driving a JAX training job
# ---------------------------------------------------------------------------
cfg = get_smoke_config("llama3.2-1b")
shape = ShapeConfig("quickstart", 128, 8, "train")
mesh = make_test_mesh((1, 1))
compat.set_mesh(mesh)

trainer = ReconfigurableTrainer(
    cfg, shape, mesh,
    tcfg=TrainConfig(learning_rate=1e-3, warmup_steps=5, total_steps=30),
    hosts=[HostSpec(0, ["xla"])],
)
print(f"negotiated transport: {trainer.transport_name}")

state = trainer.init_state(jax.random.PRNGKey(0))
state, hist = trainer.run(state, batches_for(cfg, shape), 30)
print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} over {len(hist)} steps")
assert hist[-1]["loss"] < hist[0]["loss"], "synthetic LM loss should drop"
print("quickstart OK")
