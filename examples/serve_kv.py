"""Serving example (paper §7.3): a sharded KV store whose routing stack is
negotiated and reconfigured at runtime — client-side sharding vs router.

    PYTHONPATH=src python examples/serve_kv.py
"""
import time

from repro.core import Fabric, LinkModel, LockedConn, Select, make_stack
from repro.serving.router import (
    AddressedTransport,
    ClientShardChunnel,
    KVBackend,
    KVClient,
    Router,
    ServerRouterChunnel,
)

fabric = Fabric(default_link=LinkModel(latency_s=0.0005))
backends = [KVBackend(fabric, f"kv{i}") for i in range(4)]
router = Router(fabric, "router", [b.addr for b in backends])
ep = fabric.register("cli")

# the developer writes ONE application against a Select of routing chunnels
stack = make_stack(
    Select(
        ClientShardChunnel(backends=tuple(b.addr for b in backends)),
        ServerRouterChunnel(router_addr="router"),
    ),
    AddressedTransport(ep),
)
handle = LockedConn(stack.preferred())  # preference order: client-side first
client = KVClient(fabric, ep, handle)

for i in range(32):
    client.request("put", f"user{i}", val={"n": i})
lat_client = [client.request("get", f"user{i % 32}")[1] for i in range(100)]
print(f"client-side sharding: p50 {sorted(lat_client)[50]*1e6:.0f}us")

# operator decision: backends will be re-provisioned -> switch to the router
# (an administrator choice, not an application change — the paper's pitch)
ok = handle.reconfigure(stack.options()[1])
assert ok
lat_router = [client.request("get", f"user{i % 32}")[1] for i in range(100)]
print(f"after reconfigure -> router: p50 {sorted(lat_router)[50]*1e6:.0f}us "
      f"(switches={handle.stats.switches})")

val, _ = client.request("get", "user7")
assert val["val"] == {"n": 7}, val  # data survives the routing switch
for b in backends:
    b.close()
router.close()
print("serve_kv OK")
